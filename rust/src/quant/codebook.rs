//! PQ codebook storage, including the int8-compressed variant of §3.3
//! (iPQ ⊕ int8: centroids stored as int8 codes, dividing the codebook
//! overhead by 4 while the index matrix stays log2(K) bits per block).

use crate::quant::scalar::{self, QParams};

#[derive(Debug, Clone)]
pub struct Codebook {
    /// K × d codewords, row-major, fp32 (possibly already an int8
    /// round-trip if `int8` is set).
    pub centroids: Vec<f32>,
    pub k: usize,
    pub d: usize,
    /// Set when the centroids have been int8-quantized (affects
    /// storage accounting and marks that values lie on the int8 grid).
    pub int8: Option<QParams>,
}

impl Codebook {
    pub fn new(centroids: Vec<f32>, k: usize, d: usize) -> Codebook {
        assert_eq!(centroids.len(), k * d);
        Codebook { centroids, k, d, int8: None }
    }

    #[inline]
    pub fn codeword(&self, j: usize) -> &[f32] {
        &self.centroids[j * self.d..(j + 1) * self.d]
    }

    pub fn codeword_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.centroids[j * self.d..(j + 1) * self.d]
    }

    /// Quantize the centroids themselves to int8 (Eq. 2 over the whole
    /// codebook). Returns the quantization MSE over centroid entries.
    pub fn compress_int8(&mut self) -> f64 {
        let qp = QParams::from_minmax(&self.centroids, 8);
        let before = self.centroids.clone();
        scalar::roundtrip(&mut self.centroids, &qp);
        self.int8 = Some(qp);
        before
            .iter()
            .zip(&self.centroids)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / before.len().max(1) as f64
    }

    /// Codebook storage in bits: 8·K·d when int8-compressed (Eq. 5's
    /// first term), else 32·K·d for fp32 centroids.
    pub fn storage_bits(&self) -> u64 {
        let per = if self.int8.is_some() { 8 } else { 32 };
        per * (self.k * self.d) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn cb(seed: u64, k: usize, d: usize) -> Codebook {
        let mut r = Pcg::new(seed);
        Codebook::new((0..k * d).map(|_| r.next_normal()).collect(), k, d)
    }

    #[test]
    fn codeword_slicing() {
        let c = cb(1, 8, 4);
        assert_eq!(c.codeword(3), &c.centroids[12..16]);
    }

    #[test]
    fn int8_compression_shrinks_storage_4x() {
        let mut c = cb(2, 256, 8);
        let fp32 = c.storage_bits();
        let mse = c.compress_int8();
        assert_eq!(c.storage_bits() * 4, fp32);
        assert!(mse > 0.0); // lossy
        // error per entry bounded by s/2
        let qp = c.int8.unwrap();
        assert!(mse.sqrt() <= (qp.scale / 2.0) as f64 + 1e-6);
    }

    #[test]
    fn int8_values_on_grid() {
        let mut c = cb(3, 16, 4);
        c.compress_int8();
        let qp = c.int8.unwrap();
        for &v in &c.centroids {
            // v must equal its own round-trip (already on the grid)
            assert!((v - qp.roundtrip_one(v)).abs() < 1e-6);
        }
    }
}
