//! Fixed-point scalar quantization (paper §3.1, Eq. 2).
//!
//! `q = clip(round(w/s) - z, 0, 2^N - 1)`, `ŵ = (q + z)·s`, with
//! `s = (max−min)/(2^N−1)` and `z = round(min/s)` — the same convention
//! as the L1 `fake_quant` kernel and its jnp oracle, so coordinator-side
//! round-trips match in-graph fake-quantization bit-for-bit (up to fp32
//! associativity).

/// Scale/zero-point pair for one tensor or one channel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero: f32,
    pub bits: u8,
}

impl QParams {
    /// Derive from an explicit range (observers feed clipped ranges here).
    pub fn from_range(lo: f32, hi: f32, bits: u8) -> QParams {
        let qmax = ((1u32 << bits) - 1) as f32;
        let mut scale = (hi - lo) / qmax;
        if !(scale > 0.0 && scale.is_finite()) {
            scale = 1.0; // degenerate/constant/±inf range: PyTorch-style fallback
        }
        let mut zero = (lo / scale).round();
        if !zero.is_finite() {
            zero = 0.0; // NaN/±inf bound would poison every dequantized value
        }
        QParams { scale, zero, bits }
    }

    pub fn from_minmax(data: &[f32], bits: u8) -> QParams {
        let (lo, hi) = minmax(data);
        QParams::from_range(lo, hi, bits)
    }

    pub fn qmax(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }

    #[inline]
    pub fn quantize_one(&self, w: f32) -> u8 {
        ((w / self.scale).round() - self.zero).clamp(0.0, self.qmax()) as u8
    }

    #[inline]
    pub fn dequantize_one(&self, q: u8) -> f32 {
        (q as f32 + self.zero) * self.scale
    }

    /// Fake-quant round trip of one value.
    #[inline]
    pub fn roundtrip_one(&self, w: f32) -> f32 {
        self.dequantize_one(self.quantize_one(w))
    }
}

pub fn minmax(data: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in data {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() {
        (0.0, 0.0)
    } else {
        (lo, hi)
    }
}

/// Quantize a whole tensor to intN codes.
pub fn quantize(data: &[f32], qp: &QParams) -> Vec<u8> {
    data.iter().map(|&w| qp.quantize_one(w)).collect()
}

/// Dequantize codes back to f32.
pub fn dequantize(codes: &[u8], qp: &QParams) -> Vec<f32> {
    codes.iter().map(|&q| qp.dequantize_one(q)).collect()
}

/// In-place fake-quant round-trip (what the coordinator applies before
/// evaluating an intN-quantized model through the eval artifact).
pub fn roundtrip(data: &mut [f32], qp: &QParams) {
    for w in data.iter_mut() {
        *w = qp.roundtrip_one(*w);
    }
}

/// Per-channel quantization: one QParams per row of a (rows × cols)
/// matrix (Table 10's "Quant Channel" scheme).
pub fn quantize_per_channel(
    data: &[f32],
    rows: usize,
    cols: usize,
    bits: u8,
) -> (Vec<u8>, Vec<QParams>) {
    assert_eq!(data.len(), rows * cols);
    let mut codes = vec![0u8; data.len()];
    let mut qps = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        let qp = QParams::from_minmax(row, bits);
        for (c, &w) in row.iter().enumerate() {
            codes[r * cols + c] = qp.quantize_one(w);
        }
        qps.push(qp);
    }
    (codes, qps)
}

pub fn roundtrip_per_channel(data: &mut [f32], rows: usize, cols: usize, bits: u8) {
    assert_eq!(data.len(), rows * cols);
    for r in 0..rows {
        let row = &mut data[r * cols..(r + 1) * cols];
        let qp = QParams::from_minmax(row, bits);
        for w in row.iter_mut() {
            *w = qp.roundtrip_one(*w);
        }
    }
}

/// Mean squared quantization error of a round trip (used by observers
/// and by tests asserting the error bound s²/4 per element).
pub fn quant_mse(data: &[f32], qp: &QParams) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for &w in data {
        let e = (w - qp.roundtrip_one(w)) as f64;
        acc += e * e;
    }
    acc / data.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randvec(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Pcg::new(seed);
        (0..n).map(|_| r.next_normal() * 2.0).collect()
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        for bits in [2u8, 4, 8] {
            let data = randvec(bits as u64, 1000);
            let qp = QParams::from_minmax(&data, bits);
            for &w in &data {
                let err = (w - qp.roundtrip_one(w)).abs();
                assert!(err <= qp.scale / 2.0 + 1e-5, "bits={bits} err={err} s={}", qp.scale);
            }
        }
    }

    #[test]
    fn codes_fit_bits() {
        let data = randvec(1, 500);
        for bits in [4u8, 8] {
            let qp = QParams::from_minmax(&data, bits);
            let codes = quantize(&data, &qp);
            assert!(codes.iter().all(|&c| (c as u32) < (1 << bits)));
        }
    }

    #[test]
    fn idempotent() {
        let data = randvec(2, 300);
        let qp = QParams::from_minmax(&data, 8);
        let once = dequantize(&quantize(&data, &qp), &qp);
        let twice = dequantize(&quantize(&once, &qp), &qp);
        assert_eq!(once, twice);
    }

    #[test]
    fn constant_tensor_fallback() {
        let data = vec![0.37f32; 64];
        let qp = QParams::from_minmax(&data, 8);
        assert_eq!(qp.scale, 1.0);
        // error bounded by 1/2 (rounds to nearest integer)
        assert!((data[0] - qp.roundtrip_one(data[0])).abs() <= 0.5);
    }

    #[test]
    fn extremes_map_to_range_ends() {
        let data = vec![-1.0f32, 0.0, 2.0];
        let qp = QParams::from_minmax(&data, 8);
        let codes = quantize(&data, &qp);
        assert_eq!(codes[0], 0);
        assert_eq!(codes[2], 255);
        // dequantized extremes match originals closely
        assert!((qp.dequantize_one(codes[0]) + 1.0).abs() < qp.scale);
        assert!((qp.dequantize_one(codes[2]) - 2.0).abs() < qp.scale);
    }

    #[test]
    fn per_channel_beats_or_matches_per_tensor() {
        // Rows with very different ranges: per-channel MSE must be lower.
        let mut data = randvec(3, 256);
        for (i, w) in data.iter_mut().enumerate() {
            if i < 128 {
                *w *= 100.0;
            }
        }
        let qp = QParams::from_minmax(&data, 4);
        let mse_tensor = quant_mse(&data, &qp);
        let mut per_ch = data.clone();
        roundtrip_per_channel(&mut per_ch, 2, 128, 4);
        let mse_channel: f64 = data
            .iter()
            .zip(&per_ch)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / data.len() as f64;
        assert!(mse_channel < mse_tensor, "{mse_channel} vs {mse_tensor}");
    }

    #[test]
    fn matches_python_oracle_convention() {
        // Fixed vector, compare against values computed by ref.fake_quant
        // convention: s=(hi-lo)/qmax, z=round(lo/s), q=clip(round(w/s)-z).
        let data = vec![-1.0f32, -0.5, 0.0, 0.5, 1.0];
        let qp = QParams::from_minmax(&data, 4);
        let s = 2.0 / 15.0;
        assert!((qp.scale - s).abs() < 1e-6);
        assert_eq!(qp.zero, (-1.0f32 / s).round());
        for &w in &data {
            let q = ((w / s).round() - qp.zero).clamp(0.0, 15.0);
            let expect = (q + qp.zero) * s;
            assert!((qp.roundtrip_one(w) - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn mse_empty_and_degenerate() {
        assert_eq!(quant_mse(&[], &QParams::from_range(0.0, 1.0, 8)), 0.0);
        let (lo, hi) = minmax(&[]);
        assert_eq!((lo, hi), (0.0, 0.0));
    }

    #[test]
    fn from_range_degenerate_ranges_fall_back() {
        // constant tensor: zero width ⇒ PyTorch-style scale-1 fallback,
        // and the constant value must round-trip to within 1/2
        for v in [0.0f32, 5.0, -3.25] {
            let qp = QParams::from_range(v, v, 8);
            assert_eq!(qp.scale, 1.0, "value {v}");
            assert!((qp.roundtrip_one(v) - v).abs() <= 0.5);
        }
        // inverted range (hi < lo): negative scale must also fall back
        let qp = QParams::from_range(2.0, -3.0, 4);
        assert_eq!(qp.scale, 1.0);
        // NaN bound: both scale AND zero must fall back, or every
        // dequantized value would be NaN
        let qp = QParams::from_range(f32::NAN, 1.0, 8);
        assert_eq!(qp.scale, 1.0);
        assert_eq!(qp.zero, 0.0);
        assert!(qp.roundtrip_one(0.5).is_finite());
        // infinite bound: scale would be +inf and dequantize to NaN
        let qp = QParams::from_range(-1.0, f32::INFINITY, 8);
        assert_eq!(qp.scale, 1.0);
        assert!(qp.roundtrip_one(0.5).is_finite());
    }

    #[test]
    fn minmax_ignores_nan_values() {
        // NaN-containing slices: min/max skip NaNs (f32::min/max
        // semantics), including a NaN in the first position
        assert_eq!(minmax(&[f32::NAN, 1.0, -2.0, 0.5]), (-2.0, 1.0));
        assert_eq!(minmax(&[1.0, f32::NAN]), (1.0, 1.0));
        // all-NaN behaves like empty: degenerate (0, 0) range
        assert_eq!(minmax(&[f32::NAN, f32::NAN]), (0.0, 0.0));
    }

    #[test]
    fn nan_inputs_quantize_without_panicking() {
        let data = vec![f32::NAN, 1.0, -1.0];
        let qp = QParams::from_minmax(&data, 8);
        // NaN rounds through the clamp to a finite grid value
        assert!(qp.roundtrip_one(f32::NAN).is_finite());
        let codes = quantize(&data, &qp);
        assert!((codes[0] as u32) < 256);
        assert!(dequantize(&codes, &qp).iter().all(|x| x.is_finite()));
    }

    #[test]
    fn per_channel_equals_per_tensor_on_single_row() {
        // a 1-row matrix has exactly one channel: both schemes must
        // produce identical round-trips bit-for-bit
        let data = randvec(7, 96);
        for bits in [2u8, 4, 8] {
            let qp = QParams::from_minmax(&data, bits);
            let mut pt = data.clone();
            roundtrip(&mut pt, &qp);
            let mut pc = data.clone();
            roundtrip_per_channel(&mut pc, 1, data.len(), bits);
            assert_eq!(pt, pc, "bits {bits}");
            let (codes, qps) = quantize_per_channel(&data, 1, data.len(), bits);
            assert_eq!(qps.len(), 1);
            assert_eq!(qps[0], qp);
            assert_eq!(codes, quantize(&data, &qp));
        }
    }
}
