//! Synthetic datasets (DESIGN.md §Substitutions).
//!
//! * [`MarkovCorpus`] — WikiText-103 stand-in: Zipf-distributed unigrams
//!   with first-order Markov structure (each token has a few
//!   high-probability successors) and document boundaries. A model that
//!   learns the bigram structure pushes PPL far below the unigram
//!   entropy, so LM training dynamics are non-trivial.
//! * [`make_cls_dataset`] — MNLI stand-in: sequence classification where
//!   the label is determined by which "marker" token pair dominates.
//! * [`make_img_dataset`] — ImageNet stand-in: 10 procedural pattern
//!   classes (oriented stripes, checkers, gradients, spots) with noise.

use crate::util::rng::Pcg;

pub struct MarkovCorpus {
    pub vocab: usize,
    pub tokens: Vec<i32>,
}

/// Zipf weights: p(t) ∝ 1/(t+1)^alpha.
fn zipf_weights(vocab: usize, alpha: f64) -> Vec<f64> {
    let w: Vec<f64> = (0..vocab).map(|t| 1.0 / ((t + 1) as f64).powf(alpha)).collect();
    let s: f64 = w.iter().sum();
    w.into_iter().map(|x| x / s).collect()
}

fn sample_from(weights: &[f64], rng: &mut Pcg) -> usize {
    let mut t = rng.next_f64();
    for (i, &w) in weights.iter().enumerate() {
        t -= w;
        if t <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

impl MarkovCorpus {
    /// Generate `n_tokens` tokens. Token 0 is reserved as the document
    /// boundary; docs average `doc_len` tokens. With prob `markov_p` the
    /// next token comes from the current token's 4-successor table,
    /// otherwise from the Zipf unigram.
    pub fn generate(vocab: usize, n_tokens: usize, seed: u64) -> MarkovCorpus {
        assert!(vocab >= 8);
        let mut rng = Pcg::new(seed);
        let unigram = zipf_weights(vocab - 1, 1.2); // excludes boundary 0
        let markov_p = 0.7;
        let doc_len = 256usize;

        // fixed successor table: 4 preferred successors per token, drawn
        // from the Zipf unigram so bigram structure preserves the
        // head-heavy marginal (like real text)
        let successors: Vec<[usize; 4]> = (0..vocab)
            .map(|_| {
                [
                    1 + sample_from(&unigram, &mut rng),
                    1 + sample_from(&unigram, &mut rng),
                    1 + sample_from(&unigram, &mut rng),
                    1 + sample_from(&unigram, &mut rng),
                ]
            })
            .collect();

        let mut tokens = Vec::with_capacity(n_tokens);
        let mut prev = 1usize;
        for _ in 0..n_tokens {
            let t = if rng.next_f64() < 1.0 / doc_len as f64 {
                0 // document boundary
            } else if rng.next_f64() < markov_p {
                successors[prev][rng.below(4) as usize]
            } else {
                1 + sample_from(&unigram, &mut rng)
            };
            tokens.push(t as i32);
            prev = t.max(1);
        }
        MarkovCorpus { vocab, tokens }
    }

    /// Empirical unigram entropy in nats (upper bound for a structure-
    /// blind model; the Markov structure makes lower PPL achievable).
    pub fn unigram_entropy(&self) -> f64 {
        let mut counts = vec![0usize; self.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let n = self.tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }
}

/// Sequence-classification dataset: `n_classes` marker pairs; the label
/// is the class whose markers appear most often in the sequence.
/// Returns (tokens flat B·T, labels B).
pub fn make_cls_dataset(
    n: usize,
    seq_len: usize,
    vocab: usize,
    n_classes: usize,
    seed: u64,
) -> (Vec<i32>, Vec<i32>) {
    assert!(vocab > 2 * n_classes + 2);
    let mut rng = Pcg::new(seed);
    let mut tokens = Vec::with_capacity(n * seq_len);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let label = rng.below(n_classes as u32) as usize;
        let mut seq: Vec<i32> = (0..seq_len)
            .map(|_| {
                (2 * n_classes + 1 + rng.below((vocab - 2 * n_classes - 1) as u32) as usize) as i32
            })
            .collect();
        // plant label markers at random positions (~20% of positions)
        let n_markers = (seq_len / 5).max(2);
        for _ in 0..n_markers {
            let pos = rng.below(seq_len as u32) as usize;
            let which = rng.below(2) as usize;
            seq[pos] = (1 + 2 * label + which) as i32;
        }
        tokens.extend_from_slice(&seq);
        labels.push(label as i32);
    }
    (tokens, labels)
}

/// Procedural image classification: 10 pattern classes over H×W×C
/// images in [0,1] + gaussian noise. Returns (pixels flat, labels).
pub fn make_img_dataset(
    n: usize,
    size: usize,
    channels: usize,
    seed: u64,
) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Pcg::new(seed);
    let mut pixels = Vec::with_capacity(n * size * size * channels);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let label = rng.below(10) as usize;
        let phase = rng.next_f32() * size as f32;
        let freq = 2.0 + (label % 5) as f32;
        for y in 0..size {
            for x in 0..size {
                let (fy, fx) = (y as f32, x as f32);
                let base = match label {
                    0 => ((fx + phase) * freq * 0.4).sin(),          // vertical stripes
                    1 => ((fy + phase) * freq * 0.4).sin(),          // horizontal stripes
                    2 => ((fx + fy + phase) * freq * 0.3).sin(),     // diagonal
                    3 => ((fx - fy + phase) * freq * 0.3).sin(),     // anti-diagonal
                    // checker
                    4 => (((fx + phase) * 0.8).sin() * ((fy + phase) * 0.8).sin()).signum(),
                    5 => fx / size as f32 * 2.0 - 1.0,               // x gradient
                    6 => fy / size as f32 * 2.0 - 1.0,               // y gradient
                    7 => {
                        let cx = fx - size as f32 / 2.0;
                        let cy = fy - size as f32 / 2.0;
                        ((cx * cx + cy * cy).sqrt() * 0.8 + phase).sin() // rings
                    }
                    8 => {
                        // spots
                        let sx = ((fx + phase) * 0.9).sin();
                        let sy = ((fy + phase * 0.7) * 0.9).sin();
                        (sx * sy * 2.0).tanh()
                    }
                    _ => ((fx * fy * 0.05 + phase) * 0.5).sin(),     // moiré
                };
                for c in 0..channels {
                    let chan_gain = 1.0 - 0.2 * c as f32;
                    pixels.push(
                        (0.5 + 0.4 * base * chan_gain + 0.05 * rng.next_normal())
                            .clamp(0.0, 1.0),
                    );
                }
            }
        }
        labels.push(label as i32);
    }
    (pixels, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_in_vocab_and_deterministic() {
        let c1 = MarkovCorpus::generate(64, 5_000, 7);
        let c2 = MarkovCorpus::generate(64, 5_000, 7);
        assert_eq!(c1.tokens, c2.tokens);
        assert!(c1.tokens.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    // test-only entropy estimate asserted with wide margins; map order
    // affects only f64 rounding noise, never the verdict
    #[allow(clippy::disallowed_types)]
    fn corpus_has_markov_structure() {
        // bigram entropy must be clearly below unigram entropy
        let c = MarkovCorpus::generate(128, 200_000, 1);
        let uni = c.unigram_entropy();
        // empirical conditional entropy H(next | prev)
        let mut pair = std::collections::HashMap::new();
        let mut prev_counts = vec![0usize; 128];
        for w in c.tokens.windows(2) {
            *pair.entry((w[0], w[1])).or_insert(0usize) += 1;
            prev_counts[w[0] as usize] += 1;
        }
        let n = (c.tokens.len() - 1) as f64;
        let cond: f64 = pair
            .iter()
            .map(|(&(p, _), &c_pn)| {
                let joint = c_pn as f64 / n;
                let cond_p = c_pn as f64 / prev_counts[p as usize] as f64;
                -joint * cond_p.ln()
            })
            .sum();
        assert!(cond < uni * 0.8, "cond {cond} vs uni {uni}");
    }

    #[test]
    fn corpus_zipf_head_heavy() {
        let c = MarkovCorpus::generate(256, 100_000, 2);
        let mut counts = vec![0usize; 256];
        for &t in &c.tokens {
            counts[t as usize] += 1;
        }
        let head: usize = counts[1..17].iter().sum();
        let tail: usize = counts[128..].iter().sum();
        assert!(head > tail * 3, "head {head} tail {tail}");
    }

    #[test]
    fn cls_dataset_learnable_and_balanced() {
        let (tokens, labels) = make_cls_dataset(512, 32, 256, 4, 3);
        assert_eq!(tokens.len(), 512 * 32);
        assert!(labels.iter().all(|&l| (0..4).contains(&l)));
        // markers for the true label appear in the sequence
        for i in 0..64 {
            let l = labels[i] as usize;
            let seq = &tokens[i * 32..(i + 1) * 32];
            let m1 = (1 + 2 * l) as i32;
            let m2 = (2 + 2 * l) as i32;
            assert!(
                seq.iter().any(|&t| t == m1 || t == m2),
                "example {i} lacks its own markers"
            );
        }
        // roughly balanced classes
        let mut per = [0usize; 4];
        for &l in &labels {
            per[l as usize] += 1;
        }
        assert!(per.iter().all(|&c| c > 64), "{per:?}");
    }

    #[test]
    fn img_dataset_shapes_and_range() {
        let (px, labels) = make_img_dataset(20, 16, 3, 4);
        assert_eq!(px.len(), 20 * 16 * 16 * 3);
        assert_eq!(labels.len(), 20);
        assert!(px.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(labels.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn img_classes_visually_distinct() {
        // mean intra-class pixel distance < mean inter-class distance
        let (px, labels) = make_img_dataset(100, 16, 1, 5);
        let img = |i: usize| &px[i * 256..(i + 1) * 256];
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum()
        };
        let (mut intra, mut inter, mut ni, mut ne) = (0.0, 0.0, 0, 0);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let d = dist(img(i), img(j));
                if labels[i] == labels[j] {
                    intra += d;
                    ni += 1;
                } else {
                    inter += d;
                    ne += 1;
                }
            }
        }
        assert!(intra / (ni as f64) < inter / (ne as f64));
    }
}
