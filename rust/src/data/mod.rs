//! Data substrate: synthetic corpora/datasets (WikiText/MNLI/ImageNet
//! stand-ins per DESIGN.md §Substitutions) and batchers.
pub mod batcher;
pub mod corpus;
