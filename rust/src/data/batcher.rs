//! Batchers: LM contiguous-token blocks (the paper trains on blocks of
//! contiguous tokens ignoring document boundaries, §7.6), plus shuffled
//! epoch batchers for classification and images.

use crate::util::rng::Pcg;

/// One LM batch: tokens (B·T row-major) and next-token targets.
#[derive(Debug, Clone)]
pub struct LmBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

/// Splits a token stream into `batch` contiguous lanes (fairseq-style),
/// then yields windows of `seq_len` per lane. Every token (except the
/// per-lane final target remainder) appears exactly once per epoch.
pub struct LmBatcher {
    lanes: Vec<Vec<i32>>,
    pub batch: usize,
    pub seq_len: usize,
    pos: usize,
}

impl LmBatcher {
    pub fn new(tokens: &[i32], batch: usize, seq_len: usize) -> LmBatcher {
        assert!(batch > 0 && seq_len > 0);
        let lane_len = tokens.len() / batch;
        assert!(lane_len > seq_len, "stream too short: {} tokens", tokens.len());
        let lanes = (0..batch)
            .map(|b| tokens[b * lane_len..(b + 1) * lane_len].to_vec())
            .collect();
        LmBatcher { lanes, batch, seq_len, pos: 0 }
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        (self.lanes[0].len() - 1) / self.seq_len
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }

    /// Next batch; wraps around at epoch end (callers count epochs via
    /// `batches_per_epoch`).
    pub fn next(&mut self) -> LmBatch {
        if self.pos + self.seq_len + 1 > self.lanes[0].len() {
            self.pos = 0;
        }
        let mut tokens = Vec::with_capacity(self.batch * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch * self.seq_len);
        for lane in &self.lanes {
            tokens.extend_from_slice(&lane[self.pos..self.pos + self.seq_len]);
            targets.extend_from_slice(&lane[self.pos + 1..self.pos + self.seq_len + 1]);
        }
        self.pos += self.seq_len;
        LmBatch { tokens, targets }
    }
}

/// Shuffled epoch batcher over (example, label) pairs where one example
/// is `example_len` contiguous values. Generic over i32 tokens / f32
/// pixels via two concrete types below.
pub struct EpochBatcher<T: Copy> {
    data: Vec<T>,
    labels: Vec<i32>,
    pub example_len: usize,
    pub batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Pcg,
}

impl<T: Copy> EpochBatcher<T> {
    pub fn new(
        data: Vec<T>,
        labels: Vec<i32>,
        example_len: usize,
        batch: usize,
        seed: u64,
    ) -> Self {
        assert_eq!(data.len(), labels.len() * example_len);
        assert!(labels.len() >= batch, "need at least one full batch");
        let mut rng = Pcg::new(seed);
        let mut order: Vec<usize> = (0..labels.len()).collect();
        rng.shuffle(&mut order);
        EpochBatcher { data, labels, example_len, batch, order, cursor: 0, rng }
    }

    pub fn n_examples(&self) -> usize {
        self.labels.len()
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.labels.len() / self.batch
    }

    /// Next batch (examples flat, labels); reshuffles at epoch end.
    pub fn next(&mut self) -> (Vec<T>, Vec<i32>) {
        if self.cursor + self.batch > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
        }
        let mut ex = Vec::with_capacity(self.batch * self.example_len);
        let mut lb = Vec::with_capacity(self.batch);
        for &i in &self.order[self.cursor..self.cursor + self.batch] {
            ex.extend_from_slice(&self.data[i * self.example_len..(i + 1) * self.example_len]);
            lb.push(self.labels[i]);
        }
        self.cursor += self.batch;
        (ex, lb)
    }

    /// Deterministic (unshuffled) pass for evaluation: batch `i` of
    /// `batches_per_epoch`.
    pub fn eval_batch(&self, i: usize) -> (Vec<T>, Vec<i32>) {
        let start = i * self.batch;
        assert!(start + self.batch <= self.labels.len());
        let mut ex = Vec::with_capacity(self.batch * self.example_len);
        let mut lb = Vec::with_capacity(self.batch);
        for j in start..start + self.batch {
            ex.extend_from_slice(&self.data[j * self.example_len..(j + 1) * self.example_len]);
            lb.push(self.labels[j]);
        }
        (ex, lb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_batch_shapes_and_shift() {
        let tokens: Vec<i32> = (0..1000).collect();
        let mut b = LmBatcher::new(&tokens, 4, 16);
        let batch = b.next();
        assert_eq!(batch.tokens.len(), 64);
        assert_eq!(batch.targets.len(), 64);
        // target is the next token
        for i in 0..64 {
            assert_eq!(batch.targets[i], batch.tokens[i] + 1);
        }
        // lanes are contiguous stream segments
        assert_eq!(batch.tokens[0], 0);
        assert_eq!(batch.tokens[16], 250);
    }

    #[test]
    fn lm_epoch_covers_stream_once() {
        let tokens: Vec<i32> = (0..1000).collect();
        let mut b = LmBatcher::new(&tokens, 2, 10);
        let mut seen = Vec::new();
        for _ in 0..b.batches_per_epoch() {
            seen.extend(b.next().tokens);
        }
        seen.sort();
        seen.dedup();
        // each lane of 500 contributes floor(499/10)*10 = 490 tokens
        assert_eq!(seen.len(), 980);
    }

    #[test]
    fn lm_wraps_around() {
        let tokens: Vec<i32> = (0..100).collect();
        let mut b = LmBatcher::new(&tokens, 1, 10);
        let per = b.batches_per_epoch();
        let first = b.next();
        for _ in 0..per - 1 {
            b.next();
        }
        let wrapped = b.next();
        assert_eq!(first.tokens, wrapped.tokens);
    }

    #[test]
    fn epoch_batcher_covers_all_and_reshuffles() {
        let n = 50;
        let data: Vec<i32> = (0..n * 4).collect();
        let labels: Vec<i32> = (0..n as i32).collect();
        let mut b = EpochBatcher::new(data, labels, 4, 10, 1);
        let mut seen = Vec::new();
        let mut epoch1_first = None;
        for i in 0..b.batches_per_epoch() {
            let (_, lb) = b.next();
            if i == 0 {
                epoch1_first = Some(lb.clone());
            }
            seen.extend(lb);
        }
        seen.sort();
        assert_eq!(seen, (0..50).collect::<Vec<_>>());
        let (_, lb2) = b.next(); // epoch 2 reshuffled
        assert_ne!(Some(lb2), epoch1_first);
    }

    #[test]
    fn eval_batch_deterministic() {
        let data: Vec<f32> = (0..80).map(|x| x as f32).collect();
        let labels: Vec<i32> = (0..20).collect();
        let b = EpochBatcher::new(data, labels, 4, 5, 2);
        let (e0, l0) = b.eval_batch(0);
        assert_eq!(l0, vec![0, 1, 2, 3, 4]);
        assert_eq!(e0[0..4], [0.0, 1.0, 2.0, 3.0]);
        let (_, l3) = b.eval_batch(3);
        assert_eq!(l3, vec![15, 16, 17, 18, 19]);
    }

    #[test]
    #[should_panic(expected = "too short")]
    fn lm_rejects_short_stream() {
        LmBatcher::new(&[1, 2, 3], 2, 10);
    }
}
