//! Typed model session over the AOT artifacts.
//!
//! Owns persistent device buffers for params and hat tensors so the
//! training hot loop only uploads what changed each step (L3 perf
//! plan, DESIGN.md §7): tokens/targets/scalars are tiny, grads come
//! back in one tuple download.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::model::config::ModelMeta;
use crate::model::params::ParamStore;
use crate::model::tensor::Tensor;
use crate::runtime::client::{Buffer, Executable, Runtime};
use crate::runtime::manifest::Manifest;

/// Batch input: LM/CLS feed i32 tokens, IMG feeds f32 pixels.
pub enum BatchInput<'a> {
    Tokens(&'a [i32]),
    Images(&'a [f32]),
}

pub struct ModelSession<'rt> {
    rt: &'rt Runtime,
    pub meta: ModelMeta,
    manifest: Manifest,
    exes: HashMap<String, Rc<Executable>>,
    param_bufs: Vec<Buffer>,
    hat_bufs: Vec<Buffer>,
}

impl<'rt> ModelSession<'rt> {
    /// Create a session: loads the init params from the artifact dir,
    /// uploads them, and zero-fills the hat buffers (φ_proxy default).
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        model: &str,
    ) -> Result<(ModelSession<'rt>, ParamStore)> {
        let meta = manifest.model(model)?.clone();
        let params = ParamStore::load_qnp1(&manifest.init_path(&meta))
            .context("loading init params")?;
        params.check_against(&meta)?;
        let mut session = ModelSession {
            rt,
            meta,
            manifest: manifest.clone(),
            exes: HashMap::new(),
            param_bufs: Vec::new(),
            hat_bufs: Vec::new(),
        };
        session.upload_all_params(&params)?;
        session.zero_hats()?;
        Ok((session, params))
    }

    fn exe(&mut self, entry: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.exes.get(entry) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(&self.meta, entry)?;
        let e = self.rt.compile(&path)?;
        self.exes.insert(entry.to_string(), e.clone());
        Ok(e)
    }

    /// Eagerly compile an entry (so timing loops exclude compile cost).
    pub fn warmup(&mut self, entry: &str) -> Result<()> {
        self.exe(entry).map(|_| ())
    }

    pub fn has_entry(&self, entry: &str) -> bool {
        self.meta.entry(entry).is_some()
    }

    // ------------------------------------------------ param buffers ---

    pub fn upload_all_params(&mut self, params: &ParamStore) -> Result<()> {
        params.check_against(&self.meta)?;
        self.param_bufs.clear();
        for (_, t) in params.iter() {
            self.param_bufs.push(self.rt.upload_f32(&t.data, &t.shape)?);
        }
        Ok(())
    }

    /// Re-upload a single parameter (by manifest index).
    pub fn upload_param(&mut self, idx: usize, t: &Tensor) -> Result<()> {
        anyhow::ensure!(t.shape == self.meta.params[idx].shape, "shape mismatch");
        self.param_bufs[idx] = self.rt.upload_f32(&t.data, &t.shape)?;
        Ok(())
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.meta.params.iter().position(|p| p.name == name)
    }

    /// Zero all hat buffers (φ_proxy / no-noise configuration).
    pub fn zero_hats(&mut self) -> Result<()> {
        self.hat_bufs.clear();
        for p in &self.meta.params {
            let zeros = vec![0.0f32; p.numel()];
            self.hat_bufs.push(self.rt.upload_f32(&zeros, &p.shape)?);
        }
        Ok(())
    }

    /// Upload one hat tensor (exact-PQ / mean-subvector noise images).
    pub fn upload_hat(&mut self, idx: usize, data: &[f32]) -> Result<()> {
        let p = &self.meta.params[idx];
        anyhow::ensure!(data.len() == p.numel(), "hat size mismatch for {}", p.name);
        self.hat_bufs[idx] = self.rt.upload_f32(data, &p.shape)?;
        Ok(())
    }

    fn upload_batch(&self, input: &BatchInput) -> Result<Buffer> {
        match input {
            BatchInput::Tokens(t) => self.rt.upload_i32(t, &self.meta.tokens_shape),
            BatchInput::Images(x) => self.rt.upload_f32(x, &self.meta.tokens_shape),
        }
    }

    // ------------------------------------------------------- running ---

    /// One gradient step through a grad entry:
    /// returns (mean loss, grads in manifest order).
    pub fn grad(
        &mut self,
        entry: &str,
        input: &BatchInput,
        targets: &[i32],
        layer_keep: &[f32],
        rate: f32,
        seed: i32,
    ) -> Result<(f32, Vec<Tensor>)> {
        let exe = self.exe(entry)?;
        let n = self.meta.params.len();
        anyhow::ensure!(layer_keep.len() == self.meta.n_layers, "layer_keep len");
        let batch_buf = self.upload_batch(input)?;
        let targets_buf = self.rt.upload_i32(targets, &self.meta.targets_shape)?;
        let keep_buf = self.rt.upload_f32(layer_keep, &[layer_keep.len()])?;
        let rate_buf = self.rt.scalar_f32(rate)?;
        let seed_buf = self.rt.scalar_i32(seed)?;

        let mut args: Vec<&Buffer> = Vec::with_capacity(2 * n + 5);
        args.extend(self.param_bufs.iter());
        args.extend(self.hat_bufs.iter());
        args.push(&batch_buf);
        args.push(&targets_buf);
        args.push(&keep_buf);
        args.push(&rate_buf);
        args.push(&seed_buf);

        let parts = exe.execute_f32(&args).with_context(|| format!("executing {entry}"))?;
        anyhow::ensure!(parts.len() == n + 1, "grad output arity {}", parts.len());
        let loss = parts[0][0];
        let grads = parts[1..]
            .iter()
            .zip(&self.meta.params)
            .map(|(data, p)| Tensor::from_vec(&p.shape, data.clone()))
            .collect();
        Ok((loss, grads))
    }

    /// Evaluation pass: returns (sum_nll, sum_correct) over the batch.
    pub fn eval(
        &mut self,
        entry: &str,
        input: &BatchInput,
        targets: &[i32],
        layer_keep: &[f32],
    ) -> Result<(f64, f64)> {
        let exe = self.exe(entry)?;
        let batch_buf = self.upload_batch(input)?;
        let targets_buf = self.rt.upload_i32(targets, &self.meta.targets_shape)?;
        let keep_buf = self.rt.upload_f32(layer_keep, &[layer_keep.len()])?;

        let mut args: Vec<&Buffer> = Vec::with_capacity(self.param_bufs.len() + 3);
        args.extend(self.param_bufs.iter());
        args.push(&batch_buf);
        args.push(&targets_buf);
        args.push(&keep_buf);

        let parts = exe.execute_f32(&args).with_context(|| format!("executing {entry}"))?;
        anyhow::ensure!(parts.len() == 2, "eval output arity {}", parts.len());
        Ok((parts[0][0] as f64, parts[1][0] as f64))
    }
}
