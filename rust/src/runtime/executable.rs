//! Typed model session over the AOT artifacts.
//!
//! Owns persistent device buffers for params and hat tensors so the
//! training hot loop only uploads what changed each step (L3 perf
//! plan, DESIGN.md §7): tokens/targets/scalars are tiny, grads come
//! back in one tuple download.

// per-entry executable cache is keyed lookup only — iteration order
// never reaches results (clippy.toml bans HashMap in ordered paths)
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::model::config::ModelMeta;
use crate::model::params::ParamStore;
use crate::model::tensor::Tensor;
use crate::runtime::client::{Backend, Buffer, Executable, Runtime};
use crate::runtime::manifest::Manifest;

/// Batch input: LM/CLS feed i32 tokens, IMG feeds f32 pixels.
pub enum BatchInput<'a> {
    Tokens(&'a [i32]),
    Images(&'a [f32]),
}

pub struct ModelSession<'rt> {
    rt: &'rt Runtime,
    pub meta: ModelMeta,
    manifest: Manifest,
    exes: HashMap<String, Arc<Executable>>,
    param_bufs: Vec<Buffer>,
    hat_bufs: Vec<Buffer>,
}

impl<'rt> ModelSession<'rt> {
    /// Create a session: loads the init params from the artifact dir,
    /// uploads them, and zero-fills the hat buffers (φ_proxy default).
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        model: &str,
    ) -> Result<(ModelSession<'rt>, ParamStore)> {
        let meta = manifest.model(model)?.clone();
        let params = ParamStore::load_qnp1(&manifest.init_path(&meta))
            .context("loading init params")?;
        let session = ModelSession::with_params(rt, manifest, &meta, &params)?;
        Ok((session, params))
    }

    /// Create a session around an explicit parameter set (e.g. the
    /// serving registry's current snapshot) instead of the on-disk
    /// init file. `meta` may describe a derived model id that is not
    /// in the manifest — only the entry HLO paths resolve through it,
    /// so sessions sharing one meta also share one plan via the
    /// process-wide content cache. Hat buffers are zero-filled (pure
    /// inference: no quantization noise).
    pub fn with_params(
        rt: &'rt Runtime,
        manifest: &Manifest,
        meta: &ModelMeta,
        params: &ParamStore,
    ) -> Result<ModelSession<'rt>> {
        let mut session = ModelSession {
            rt,
            meta: meta.clone(),
            manifest: manifest.clone(),
            exes: HashMap::new(),
            param_bufs: Vec::new(),
            hat_bufs: Vec::new(),
        };
        session.upload_all_params(params)?;
        session.zero_hats()?;
        Ok(session)
    }

    fn exe(&mut self, entry: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.exes.get(entry) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(&self.meta, entry)?;
        let e = self.rt.compile(&path)?;
        self.exes.insert(entry.to_string(), e.clone());
        Ok(e)
    }

    /// Eagerly compile an entry (so timing loops exclude compile cost).
    pub fn warmup(&mut self, entry: &str) -> Result<()> {
        self.exe(entry).map(|_| ())
    }

    /// Bound the backend's worker threads (0 ⇒ all cores). Forwarded to
    /// the shared [`Runtime`]; `TrainConfig.threads` lands here so one
    /// knob governs both the host quantization engine and the backend.
    pub fn set_backend_threads(&self, threads: usize) {
        self.rt.set_threads(threads);
    }

    /// Effective backend worker count (resolved, ≥ 1).
    pub fn backend_threads(&self) -> usize {
        self.rt.threads()
    }

    pub fn has_entry(&self, entry: &str) -> bool {
        self.meta.entry(entry).is_some()
    }

    // ------------------------------------------------ param buffers ---

    pub fn upload_all_params(&mut self, params: &ParamStore) -> Result<()> {
        params.check_against(&self.meta)?;
        self.param_bufs.clear();
        for (_, t) in params.iter() {
            self.param_bufs.push(self.rt.upload_f32(&t.data, &t.shape)?);
        }
        Ok(())
    }

    /// Re-upload a single parameter (by manifest index).
    pub fn upload_param(&mut self, idx: usize, t: &Tensor) -> Result<()> {
        anyhow::ensure!(t.shape == self.meta.params[idx].shape, "shape mismatch");
        self.param_bufs[idx] = self.rt.upload_f32(&t.data, &t.shape)?;
        Ok(())
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.meta.params.iter().position(|p| p.name == name)
    }

    /// Zero all hat buffers (φ_proxy / no-noise configuration).
    pub fn zero_hats(&mut self) -> Result<()> {
        self.hat_bufs.clear();
        for p in &self.meta.params {
            let zeros = vec![0.0f32; p.numel()];
            self.hat_bufs.push(self.rt.upload_f32(&zeros, &p.shape)?);
        }
        Ok(())
    }

    /// Upload one hat tensor (exact-PQ / mean-subvector noise images).
    pub fn upload_hat(&mut self, idx: usize, data: &[f32]) -> Result<()> {
        let p = &self.meta.params[idx];
        anyhow::ensure!(data.len() == p.numel(), "hat size mismatch for {}", p.name);
        self.hat_bufs[idx] = self.rt.upload_f32(data, &p.shape)?;
        Ok(())
    }

    fn upload_batch(&self, input: &BatchInput) -> Result<Buffer> {
        match input {
            BatchInput::Tokens(t) => self.rt.upload_i32(t, &self.meta.tokens_shape),
            BatchInput::Images(x) => self.rt.upload_f32(x, &self.meta.tokens_shape),
        }
    }

    // ------------------------------------------------------- running ---

    /// One gradient step through a grad entry:
    /// returns (mean loss, grads in manifest order).
    pub fn grad(
        &mut self,
        entry: &str,
        input: &BatchInput,
        targets: &[i32],
        layer_keep: &[f32],
        rate: f32,
        seed: i32,
    ) -> Result<(f32, Vec<Tensor>)> {
        let exe = self.exe(entry)?;
        let n = self.meta.params.len();
        anyhow::ensure!(layer_keep.len() == self.meta.n_layers, "layer_keep len");
        let batch_buf = self.upload_batch(input)?;
        let targets_buf = self.rt.upload_i32(targets, &self.meta.targets_shape)?;
        let keep_buf = self.rt.upload_f32(layer_keep, &[layer_keep.len()])?;
        let rate_buf = self.rt.scalar_f32(rate)?;
        let seed_buf = self.rt.scalar_i32(seed)?;

        let mut args: Vec<&Buffer> = Vec::with_capacity(2 * n + 5);
        args.extend(self.param_bufs.iter());
        args.extend(self.hat_bufs.iter());
        args.push(&batch_buf);
        args.push(&targets_buf);
        args.push(&keep_buf);
        args.push(&rate_buf);
        args.push(&seed_buf);

        let parts = exe
            .execute_f32_with(&args, self.rt.threads())
            .with_context(|| format!("executing {entry}"))?;
        anyhow::ensure!(parts.len() == n + 1, "grad output arity {}", parts.len());
        let loss = parts[0][0];
        let grads = parts[1..]
            .iter()
            .zip(&self.meta.params)
            .map(|(data, p)| Tensor::from_vec(&p.shape, data.clone()))
            .collect();
        Ok((loss, grads))
    }

    /// Evaluation pass: returns (sum_nll, sum_correct) over the batch.
    pub fn eval(
        &mut self,
        entry: &str,
        input: &BatchInput,
        targets: &[i32],
        layer_keep: &[f32],
    ) -> Result<(f64, f64)> {
        let exe = self.exe(entry)?;
        let batch_buf = self.upload_batch(input)?;
        let targets_buf = self.rt.upload_i32(targets, &self.meta.targets_shape)?;
        let keep_buf = self.rt.upload_f32(layer_keep, &[layer_keep.len()])?;

        let mut args: Vec<&Buffer> = Vec::with_capacity(self.param_bufs.len() + 3);
        args.extend(self.param_bufs.iter());
        args.push(&batch_buf);
        args.push(&targets_buf);
        args.push(&keep_buf);

        let parts = exe
            .execute_f32_with(&args, self.rt.threads())
            .with_context(|| format!("executing {entry}"))?;
        anyhow::ensure!(parts.len() == 2, "eval output arity {}", parts.len());
        Ok((parts[0][0] as f64, parts[1][0] as f64))
    }

    /// Evaluate a *macro-batch*: `input`/`targets` carry `M` eval
    /// batches concatenated along the leading dimension. The backend
    /// shards them into `M` independent entry invocations across its
    /// worker threads and returns the per-batch `(sum_nll,
    /// sum_correct)` pairs in batch order — bit-identical to `M`
    /// sequential [`ModelSession::eval`] calls at any thread count
    /// (DESIGN.md §4).
    pub fn eval_batched(
        &mut self,
        entry: &str,
        input: &BatchInput,
        targets: &[i32],
        layer_keep: &[f32],
    ) -> Result<Vec<(f64, f64)>> {
        let exe = self.exe(entry)?;
        let per_input: usize = self.meta.tokens_shape.iter().product();
        let per_target: usize = self.meta.targets_shape.iter().product();
        let len = match input {
            BatchInput::Tokens(t) => t.len(),
            BatchInput::Images(x) => x.len(),
        };
        anyhow::ensure!(
            per_input > 0 && len % per_input == 0,
            "macro-batch input length {len} is not a multiple of {per_input}"
        );
        let m = len / per_input;
        anyhow::ensure!(
            targets.len() == m * per_target,
            "macro-batch targets length {} != {m} x {per_target}",
            targets.len()
        );
        if self.rt.backend() == Backend::Pjrt {
            // PJRT has no batched seam (yet): run the shards serially —
            // identical results, just no host-side parallelism. When
            // the stub (or a capability-poor plugin) declines, the
            // typed `BackendError` payload survives this context wrap,
            // so a serving caller can degrade to 503 instead of
            // treating the whole macro-batch as an internal error.
            let mut out = Vec::with_capacity(m);
            for s in 0..m {
                let inp = match input {
                    BatchInput::Tokens(t) => {
                        BatchInput::Tokens(&t[s * per_input..(s + 1) * per_input])
                    }
                    BatchInput::Images(x) => {
                        BatchInput::Images(&x[s * per_input..(s + 1) * per_input])
                    }
                };
                let tg = &targets[s * per_target..(s + 1) * per_target];
                let r = self
                    .eval(entry, &inp, tg, layer_keep)
                    .with_context(|| format!("PJRT serial fallback, shard {s}/{m}"))?;
                out.push(r);
            }
            return Ok(out);
        }
        let mut tshape = self.meta.tokens_shape.clone();
        tshape[0] *= m;
        let mut gshape = self.meta.targets_shape.clone();
        gshape[0] *= m;
        let batch_buf = match input {
            BatchInput::Tokens(t) => self.rt.upload_i32(t, &tshape)?,
            BatchInput::Images(x) => self.rt.upload_f32(x, &tshape)?,
        };
        let targets_buf = self.rt.upload_i32(targets, &gshape)?;
        let keep_buf = self.rt.upload_f32(layer_keep, &[layer_keep.len()])?;

        let mut args: Vec<&Buffer> = Vec::with_capacity(self.param_bufs.len() + 3);
        args.extend(self.param_bufs.iter());
        args.push(&batch_buf);
        args.push(&targets_buf);
        args.push(&keep_buf);

        let shards = exe
            .execute_f32_batched(&args, self.rt.threads())
            .with_context(|| format!("executing {entry} (batched x{m})"))?;
        shards
            .into_iter()
            .map(|parts| {
                anyhow::ensure!(parts.len() == 2, "eval output arity {}", parts.len());
                Ok((parts[0][0] as f64, parts[1][0] as f64))
            })
            .collect()
    }
}
