//! Static plan verifier (DESIGN.md §8): prove every invariant a
//! compiled [`Plan`] relies on *before* it executes, and reject bad
//! plans with typed, instruction-addressed diagnostics instead of
//! corrupting a training run.
//!
//! The interpreter's optimization layers — last-use liveness with
//! in-place buffer moves, counted-`while` superinstructions, the
//! native threefry kernel, sharded kernels — are each only sound under
//! structural preconditions that [`Plan::compile`] derives from the
//! HLO. Until now those preconditions were enforced dynamically
//! (golden fixture tests, the Python mirror); a planner bug on an op
//! pattern outside the fixture would ship silently. This module checks
//! them statically, per plan:
//!
//! * **Schedule / liveness** ([`DiagKind::StaleRead`],
//!   [`DiagKind::Structure`]): operands are defined before use, no
//!   step reads a register after its `free_after` point, every
//!   non-root register is freed exactly once, the root is never freed.
//! * **In-place legality** ([`DiagKind::InPlace`]): a `take` (move)
//!   flag is only legal on an operand's unique, final use — a wrong
//!   flag means an in-place kernel mutates (or steals) a buffer some
//!   later step still needs.
//! * **Shape/dtype agreement** ([`DiagKind::Type`]): every
//!   instruction's declared result shape is re-derived from its
//!   operands' declared shapes per the op's semantics, including
//!   through `call`/`while`/`reduce`/`scatter` sub-computations.
//! * **Fused-region preconditions** ([`DiagKind::Fusion`]): each
//!   `Fused` annotation (single-binary-op region, counted loop,
//!   threefry round body, elementwise-chain superinstruction) is
//!   re-proved from the instructions — for chains, the claimed
//!   membership must be a bijection with the interior markers, every
//!   elided register must be unobservable outside the chain, and the
//!   slot assignment, tape, take flags and in-place slot must agree
//!   with an independent re-derivation.
//! * **Shard safety** ([`DiagKind::ShardSafety`]): every step that can
//!   dispatch a kernel that shards under the `threads` knob must name
//!   a kernel in [`SHARD_REGISTRY`], where each entry carries its
//!   determinism argument (per-element independence or ascending-shard
//!   merge). A sharding step outside the registry is an error — new
//!   kernels must declare *why* they are thread-count-invariant.
//!
//! **Independence rule.** The verifier re-derives liveness, move flags
//! and fusion legality from the plan's instruction list with its own
//! code — it never calls [`super::plan`]'s `analyze()` or
//! [`super::fuse`]'s matchers — so a bug in the planner cannot vouch
//! for itself. When `plan.rs` or `fuse.rs` change an invariant, the
//! corresponding re-derivation here must change *in a separate code
//! path* (see the keep-in-sync notes at their definitions).
//!
//! **Wiring.** Debug builds and tests verify every compiled plan
//! unconditionally ([`should_verify`]); release builds opt in with
//! `QN_PLAN_VERIFY=1`. The runtime verifies before inserting a plan
//! into the process-wide cache (`runtime/client.rs`), and
//! `qn lint-plan <hlo.txt>` prints diagnostics plus a [`PlanCensus`]
//! for any HLO file.

use std::collections::BTreeMap;
use std::fmt;

use crate::runtime::interp::fuse::{ChainInput, ChainSpec, CountedLoop};
use crate::runtime::interp::ops::TapeOp;
use crate::runtime::interp::parser::{BinaryOp, CmpDir, Instr, Op};
use crate::runtime::interp::plan::{op_label, CompPlan, Fused, Plan};
use crate::runtime::interp::value::{Buf, ElemType, Shape};

// --------------------------------------------------------- diagnostics ---

/// What kind of invariant a diagnostic reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiagKind {
    /// A register is read after its free point.
    StaleRead,
    /// A move (`take`) flag on an operand that is not a unique final
    /// use — an in-place kernel would mutate or steal a live buffer.
    InPlace,
    /// Declared result shape/dtype disagrees with the one re-derived
    /// from the operands.
    Type,
    /// A `Fused` annotation whose preconditions do not hold on the
    /// instructions it covers.
    Fusion,
    /// A step can dispatch a sharding kernel that is not declared in
    /// [`SHARD_REGISTRY`].
    ShardSafety,
    /// Malformed plan structure: operand ordering, arity mismatches,
    /// double frees, bad computation references.
    Structure,
}

impl fmt::Display for DiagKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DiagKind::StaleRead => "stale-read",
            DiagKind::InPlace => "in-place",
            DiagKind::Type => "type",
            DiagKind::Fusion => "fusion",
            DiagKind::ShardSafety => "shard-safety",
            DiagKind::Structure => "structure",
        };
        f.write_str(s)
    }
}

/// One verifier finding, addressed to a specific instruction.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Computation name (e.g. `ENTRY main.1`'s `main.1`).
    pub comp: String,
    /// Instruction name (e.g. `add.42`).
    pub instr: String,
    /// Instruction index within the computation.
    pub index: usize,
    pub kind: DiagKind,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}::{} (#{}): [{}] {}",
            self.comp, self.instr, self.index, self.kind, self.message
        )
    }
}

/// Render a diagnostic list one-per-line (panic messages, lint output).
pub fn render(diags: &[Diagnostic]) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for d in diags {
        let _ = writeln!(s, "  {d}");
    }
    s
}

/// Should compiled plans be verified in this process? Always in debug
/// builds and tests; opt-in via `QN_PLAN_VERIFY=1` (any non-empty,
/// non-`0` value) in release.
pub fn should_verify() -> bool {
    cfg!(debug_assertions)
        || std::env::var("QN_PLAN_VERIFY").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

// ------------------------------------------------ shard-safety registry ---

/// Why a sharded kernel is bit-identical at any worker count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardDeterminism {
    /// Every output element is computed wholly by one worker with the
    /// same scalar code regardless of which worker owns it.
    PerElement,
    /// Workers own disjoint ascending ranges and results merge in
    /// ascending shard order, identical to the serial visit order.
    AscendingMerge,
}

/// One declared sharding kernel with its determinism argument.
#[derive(Debug, Clone, Copy)]
pub struct ShardKernel {
    /// Key produced by [`sharding_kernel`] for matching steps.
    pub name: &'static str,
    pub determinism: ShardDeterminism,
    /// One-line justification (the auditable argument).
    pub rationale: &'static str,
}

/// Every kernel the planned executor may shard under the `threads`
/// knob, with its determinism argument. A step that can dispatch a
/// sharding kernel *not* listed here fails verification — extending
/// the executor with a new sharded kernel requires declaring it here
/// (and arguing its thread-count invariance; see DESIGN.md §8).
pub const SHARD_REGISTRY: &[ShardKernel] = &[
    ShardKernel {
        name: "unary[elementwise]",
        determinism: ShardDeterminism::PerElement,
        rationale: "each element is mapped independently by the same scalar helper",
    },
    ShardKernel {
        name: "binary[elementwise]",
        determinism: ShardDeterminism::PerElement,
        rationale: "each element pair is combined independently by the same scalar helper",
    },
    ShardKernel {
        name: "select[elementwise]",
        determinism: ShardDeterminism::PerElement,
        rationale: "each element picks one branch independently of every other element",
    },
    ShardKernel {
        name: "chain[elementwise]",
        determinism: ShardDeterminism::PerElement,
        rationale: "each element evaluates the whole compiled tape independently with the \
                    same scalar helpers as the standalone kernels, never reading another \
                    element (in-place lanes are read before the element's own store)",
    },
    ShardKernel {
        name: "dot[packed]",
        determinism: ShardDeterminism::PerElement,
        rationale: "each output element's 4-way ascending-k accumulation runs wholly on one \
                    worker; lane tiles batch independent columns without regrouping any sum",
    },
    ShardKernel {
        name: "reduce[fused]",
        determinism: ShardDeterminism::AscendingMerge,
        rationale: "workers fold disjoint ascending cell ranges, merged in shard order",
    },
    ShardKernel {
        name: "call[threefry2x32]",
        determinism: ShardDeterminism::PerElement,
        rationale: "each u32 lane's round chain is independent of every other lane",
    },
    ShardKernel {
        name: "conv[direct]",
        determinism: ShardDeterminism::PerElement,
        rationale: "each output cell's ascending tap/channel accumulation runs on one worker",
    },
    ShardKernel {
        name: "reduce-window[fused]",
        determinism: ShardDeterminism::PerElement,
        rationale: "each output cell folds its own window's ascending taps wholly on one worker",
    },
];

/// Which sharding kernel (registry key) a planned step can dispatch,
/// mirroring the executor's dispatch sites in `plan.rs` — elementwise
/// unary/binary/select (in-place or CoW+sharded), the packed dot,
/// fused reduces, the native threefry call, direct convolution and
/// fused reduce-windows. Scatter, reverse and the generic
/// reduce/reduce-window/while/call paths are serial per invocation and
/// return None. Keep in sync with `Executor::step`.
pub fn sharding_kernel(ins: &Instr, fused: &Fused) -> Option<&'static str> {
    match (&ins.op, fused) {
        // chain dispatch precedes the per-op arms: a chain root runs
        // the tape kernel instead of its own op's kernel, and an
        // elided interior never dispatches anything
        (_, Fused::Chain(_)) => Some("chain[elementwise]"),
        (_, Fused::ChainInterior { .. }) => None,
        (Op::Unary(_), _) => Some("unary[elementwise]"),
        (Op::Binary(_), _) => Some("binary[elementwise]"),
        (Op::Select, _) => Some("select[elementwise]"),
        (Op::Dot(_), _) => Some("dot[packed]"),
        (Op::Reduce { .. }, Fused::Bin { .. }) => Some("reduce[fused]"),
        (Op::Call { .. }, Fused::Threefry) => Some("call[threefry2x32]"),
        (Op::Convolution(_), _) => Some("conv[direct]"),
        (Op::ReduceWindow { .. }, Fused::Bin { .. }) => Some("reduce-window[fused]"),
        _ => None,
    }
}

// -------------------------------------------------------------- verify ---

/// Verify every computation of a compiled plan against the invariants
/// in the module docs. Returns all findings (empty = plan is clean).
pub fn verify(plan: &Plan) -> Vec<Diagnostic> {
    verify_with_registry(plan, SHARD_REGISTRY)
}

/// [`verify`] against an explicit shard-safety registry (test hook:
/// an empty registry must reject every sharding step).
pub fn verify_with_registry(plan: &Plan, registry: &[ShardKernel]) -> Vec<Diagnostic> {
    let mut v = Verifier { plan, registry, diags: Vec::new() };
    v.run();
    v.diags
}

struct Verifier<'p> {
    plan: &'p Plan,
    registry: &'p [ShardKernel],
    diags: Vec<Diagnostic>,
}

impl<'p> Verifier<'p> {
    fn diag(&mut self, ci: usize, si: usize, kind: DiagKind, message: String) {
        let comp = &self.plan.comps[ci];
        let instr =
            comp.instrs.get(si).map(|i| i.name.clone()).unwrap_or_else(|| "<root>".into());
        self.diags.push(Diagnostic { comp: comp.name.clone(), instr, index: si, kind, message });
    }

    fn run(&mut self) {
        if self.plan.entry >= self.plan.comps.len() {
            // no computation to address: fabricate a root-level finding
            self.diags.push(Diagnostic {
                comp: "<module>".into(),
                instr: "<entry>".into(),
                index: self.plan.entry,
                kind: DiagKind::Structure,
                message: format!(
                    "entry computation index {} out of range ({} computations)",
                    self.plan.entry,
                    self.plan.comps.len()
                ),
            });
            return;
        }
        let n = self.plan.comps.len();
        let mut sound = vec![false; n];
        for (ci, s) in sound.iter_mut().enumerate() {
            *s = self.check_comp(ci);
        }
        // type/fusion/shard checks follow operand and computation
        // references across the whole module; only run them when every
        // computation is structurally sound, so a corrupt plan yields
        // diagnostics instead of out-of-range panics
        if sound.iter().all(|&s| s) {
            for ci in 0..n {
                for si in 0..self.plan.comps[ci].instrs.len() {
                    self.check_types(ci, si);
                    self.check_fusion(ci, si);
                    self.check_shard(ci, si);
                }
            }
            self.check_entry_params();
        }
    }

    /// The entry-parameter shape table must mirror the entry
    /// computation's Parameter declarations (batched execution slices
    /// inputs against it).
    fn check_entry_params(&mut self) {
        let e = &self.plan.comps[self.plan.entry];
        if self.plan.entry_params.len() != e.n_params {
            self.diag(
                self.plan.entry,
                e.root.min(e.instrs.len()),
                DiagKind::Structure,
                format!(
                    "entry_params arity {} != entry n_params {}",
                    self.plan.entry_params.len(),
                    e.n_params
                ),
            );
            return;
        }
        let mut pending = Vec::new();
        for (si, ins) in e.instrs.iter().enumerate() {
            if let Op::Parameter(i) = &ins.op {
                if self.plan.entry_params.get(*i).map(|s| s.as_ref()) != Some(Some(&ins.shape)) {
                    pending.push((si, *i));
                }
            }
        }
        for (si, i) in pending {
            self.diag(
                self.plan.entry,
                si,
                DiagKind::Structure,
                format!("entry_params[{i}] does not record this parameter's declared shape"),
            );
        }
    }

    /// Schedule-level checks for one computation: root/annotation
    /// bounds, structure, liveness. Returns whether the deeper passes
    /// may index through it.
    fn check_comp(&mut self, ci: usize) -> bool {
        let comp = &self.plan.comps[ci];
        let n = comp.instrs.len();
        if comp.root >= n {
            self.diag(
                ci,
                0,
                DiagKind::Structure,
                format!("root register {} out of range ({n} instructions)", comp.root),
            );
            return false;
        }
        if comp.free_after.len() != n || comp.take.len() != n || comp.fused.len() != n {
            self.diag(
                ci,
                0,
                DiagKind::Structure,
                format!(
                    "annotation arity mismatch: {n} instructions, {} free lists, {} take rows, \
                     {} fusion slots",
                    comp.free_after.len(),
                    comp.take.len(),
                    comp.fused.len()
                ),
            );
            return false;
        }
        let structure_ok = self.check_structure(ci);
        self.check_liveness(ci);
        structure_ok
    }

    /// Operand ordering, take-row arity, parameter declarations and
    /// computation references. Returns false if later passes must not
    /// index through this computation.
    fn check_structure(&mut self, ci: usize) -> bool {
        let comp = &self.plan.comps[ci];
        let n_comps = self.plan.comps.len();
        let mut ok = true;
        let mut findings = Vec::new();
        let mut seen_params = vec![false; comp.n_params];
        for (si, ins) in comp.instrs.iter().enumerate() {
            if comp.take[si].len() != ins.operands.len() {
                findings.push((
                    si,
                    format!(
                        "take row has {} flags for {} operands",
                        comp.take[si].len(),
                        ins.operands.len()
                    ),
                ));
                ok = false;
            }
            for &o in &ins.operands {
                if o >= si {
                    findings.push((
                        si,
                        format!("operand register {o} is not defined before this step"),
                    ));
                    ok = false;
                }
            }
            match &ins.op {
                Op::Parameter(i) => {
                    if *i >= comp.n_params {
                        findings.push((
                            si,
                            format!("parameter {i} out of range ({} declared)", comp.n_params),
                        ));
                    } else if std::mem::replace(&mut seen_params[*i], true) {
                        // the executor moves the argument out of its
                        // slot, so a second read would find nothing
                        findings
                            .push((si, format!("parameter {i} is declared more than once")));
                    }
                }
                Op::Call { comp: t } => {
                    if *t >= n_comps {
                        findings.push((si, format!("call target {t} out of range")));
                        ok = false;
                    }
                }
                Op::While { cond, body } => {
                    if *cond >= n_comps || *body >= n_comps {
                        findings.push((
                            si,
                            format!("while cond/body reference ({cond}, {body}) out of range"),
                        ));
                        ok = false;
                    }
                }
                Op::Reduce { comp: t, .. }
                | Op::Scatter { comp: t, .. }
                | Op::ReduceWindow { comp: t, .. } => {
                    if *t >= n_comps {
                        findings.push((si, format!("region target {t} out of range")));
                        ok = false;
                    }
                }
                _ => {}
            }
        }
        for (si, msg) in findings {
            self.diag(ci, si, DiagKind::Structure, msg);
        }
        ok
    }

    /// Independently re-derive last uses from the instruction list and
    /// check `free_after` / `take` against them. This is deliberately
    /// NOT a call into `plan::analyze` — the point is that a planner
    /// bug cannot vouch for itself.
    ///
    /// Uses are counted at their *effective* site: a read by a step
    /// elided into an elementwise chain physically happens when the
    /// chain root runs, so that is where its register must still be
    /// live. The `ChainInterior` back-pointers consulted for the
    /// mapping are themselves re-proved by `check_fusion`.
    fn check_liveness(&mut self, ci: usize) {
        let comp = &self.plan.comps[ci];
        let n = comp.instrs.len();
        // where step si's operand reads physically happen (defensive
        // against a corrupt back-pointer, which check_fusion reports)
        let eff = |si: usize| match comp.fused[si] {
            Fused::ChainInterior { root } if root < n => root,
            _ => si,
        };
        // my own last-use table: latest *effective* step reading
        // register r (effective sites are not monotone in si, so fold
        // the maximum instead of keeping the final write)
        let mut last_use: Vec<Option<usize>> = vec![None; n];
        for (si, ins) in comp.instrs.iter().enumerate() {
            for &o in &ins.operands {
                if o < n {
                    let s = eff(si);
                    last_use[o] = Some(last_use[o].map_or(s, |l| l.max(s)));
                }
            }
        }
        let mut findings = Vec::new();
        // first free site per register, with the structural free checks
        let mut free_at: Vec<Option<usize>> = vec![None; n];
        for (si, frees) in comp.free_after.iter().enumerate() {
            for &r in frees {
                if r >= n {
                    findings.push((
                        si,
                        DiagKind::Structure,
                        format!("frees register {r}, which does not exist"),
                    ));
                    continue;
                }
                if r == comp.root {
                    findings.push((
                        si,
                        DiagKind::Structure,
                        format!("frees the root register {r}"),
                    ));
                    continue;
                }
                if r > si {
                    findings.push((
                        si,
                        DiagKind::Structure,
                        format!("frees register {r} before it is computed"),
                    ));
                }
                if last_use[r].is_some_and(|l| l > si) {
                    findings.push((
                        si,
                        DiagKind::StaleRead,
                        format!("frees register {r}, but a later step still reads it"),
                    ));
                }
                if free_at[r].is_some() {
                    findings.push((
                        si,
                        DiagKind::Structure,
                        format!("register {r} is freed twice"),
                    ));
                } else {
                    free_at[r] = Some(si);
                }
            }
        }
        for (si, ins) in comp.instrs.iter().enumerate() {
            let elided = matches!(comp.fused[si], Fused::ChainInterior { .. });
            for (k, &o) in ins.operands.iter().enumerate() {
                if o >= si {
                    continue; // reported by check_structure
                }
                if free_at[o].is_some_and(|f| f < eff(si)) {
                    findings.push((
                        si,
                        DiagKind::StaleRead,
                        format!("reads register {o} after its free point"),
                    ));
                }
                if comp.take[si].get(k) == Some(&true) {
                    let dup = ins.operands.iter().filter(|&&x| x == o).count() > 1;
                    if elided {
                        // the step never executes — its reads happen at
                        // the chain root, governed by the spec's own
                        // take flags, so a move flag here is a lie
                        findings.push((
                            si,
                            DiagKind::InPlace,
                            format!(
                                "operand {k} carries a move flag on a step elided into a chain"
                            ),
                        ));
                    } else if o == comp.root {
                        findings.push((
                            si,
                            DiagKind::InPlace,
                            format!("operand {k} moves the root register {o}"),
                        ));
                    } else if dup {
                        findings.push((
                            si,
                            DiagKind::InPlace,
                            format!(
                                "operand {k} moves register {o}, which this step reads twice"
                            ),
                        ));
                    } else if last_use[o] != Some(si) {
                        findings.push((
                            si,
                            DiagKind::InPlace,
                            format!(
                                "operand {k} moves register {o}, but step {} still reads it",
                                last_use[o].unwrap_or(o)
                            ),
                        ));
                    }
                }
            }
        }
        for (r, f) in free_at.iter().enumerate() {
            if f.is_none() && r != comp.root {
                findings.push((
                    r,
                    DiagKind::Structure,
                    format!("register {r} is never freed"),
                ));
            }
        }
        for (si, kind, msg) in findings {
            self.diag(ci, si, kind, msg);
        }
    }

    // ------------------------------------------------------ type check ---

    /// Declared shape of operand `k` of step `si` (structure already
    /// validated: operands index earlier instructions).
    fn oshape(&self, ci: usize, si: usize, k: usize) -> &'p Shape {
        let comp = &self.plan.comps[ci];
        &comp.instrs[comp.instrs[si].operands[k]].shape
    }

    /// Operand `k` as (dtype, dims), or a Type diagnostic.
    fn oarr(&mut self, ci: usize, si: usize, k: usize) -> Option<(ElemType, Vec<usize>)> {
        match self.oshape(ci, si, k) {
            Shape::Array { ty, dims } => Some((*ty, dims.clone())),
            Shape::Tuple(_) => {
                self.diag(
                    ci,
                    si,
                    DiagKind::Type,
                    format!("operand {k} is a tuple where an array is required"),
                );
                None
            }
        }
    }

    fn ty_err(&mut self, ci: usize, si: usize, msg: String) {
        self.diag(ci, si, DiagKind::Type, msg);
    }

    /// Re-derive step `si`'s result shape from its operands' declared
    /// shapes and compare against the declared result shape.
    fn check_types(&mut self, ci: usize, si: usize) {
        let comp = &self.plan.comps[ci];
        let ins = &comp.instrs[si];
        let declared = ins.shape.clone();
        let nops = ins.operands.len();
        // fixed-arity ops: validate before any operand indexing (a
        // corrupted plan must produce a diagnostic, never a panic);
        // tuple/call/concatenate/reduce validate their own arity below
        let need = match &ins.op {
            Op::Parameter(_) | Op::Constant(_) | Op::Iota { .. } => Some(0),
            Op::GetTupleElement(_)
            | Op::While { .. }
            | Op::Broadcast { .. }
            | Op::Reshape
            | Op::Transpose { .. }
            | Op::Slice { .. }
            | Op::Convert
            | Op::BitcastConvert
            | Op::Reverse { .. }
            | Op::Unary(_) => Some(1),
            Op::Compare { .. }
            | Op::Binary(_)
            | Op::Dot(_)
            | Op::Gather(_)
            | Op::Convolution(_)
            | Op::ReduceWindow { .. } => Some(2),
            Op::Select | Op::Scatter { .. } => Some(3),
            Op::Tuple | Op::Call { .. } | Op::Concatenate { .. } | Op::Reduce { .. } => None,
        };
        if let Some(want) = need {
            if nops != want {
                return self.diag(
                    ci,
                    si,
                    DiagKind::Structure,
                    format!("op takes {want} operands, got {nops}"),
                );
            }
        }
        let decl_arr = match &declared {
            Shape::Array { ty, dims } => Some((*ty, dims.clone())),
            Shape::Tuple(_) => None,
        };
        match &ins.op {
            Op::Parameter(_) => {} // the declaration IS the shape
            Op::Constant(c) => {
                let want = Shape::Array { ty: c.ty(), dims: c.dims.clone() };
                if declared != want {
                    self.ty_err(
                        ci,
                        si,
                        format!(
                            "constant payload is {}{:?}, declared {declared:?}",
                            c.ty().name(),
                            c.dims
                        ),
                    );
                }
            }
            Op::Tuple => {
                let elems: Vec<Shape> =
                    (0..nops).map(|k| self.oshape(ci, si, k).clone()).collect();
                if declared != Shape::Tuple(elems) {
                    self.ty_err(ci, si, "tuple shape != operand shapes".into());
                }
            }
            Op::GetTupleElement(i) => match self.oshape(ci, si, 0) {
                Shape::Tuple(ts) => match ts.get(*i) {
                    Some(t) if *t == declared => {}
                    Some(t) => {
                        let t = t.clone();
                        self.ty_err(ci, si, format!("element {i} is {t:?}, declared {declared:?}"));
                    }
                    None => self.ty_err(ci, si, format!("tuple index {i} out of range")),
                },
                Shape::Array { .. } => {
                    self.ty_err(ci, si, "get-tuple-element of an array".into())
                }
            },
            Op::Call { comp: t } => {
                let params = self.param_shapes(*t);
                if params.len() != nops {
                    self.ty_err(
                        ci,
                        si,
                        format!("call passes {nops} args, callee takes {}", params.len()),
                    );
                } else {
                    for (k, want) in params.into_iter().enumerate() {
                        match want {
                            Some(w) if w == *self.oshape(ci, si, k) => {}
                            Some(w) => self.ty_err(
                                ci,
                                si,
                                format!("arg {k} is {:?}, callee expects {w:?}",
                                    self.oshape(ci, si, k)),
                            ),
                            None => {} // callee never reads this parameter
                        }
                    }
                }
                let root = self.root_shape(*t);
                if root != declared {
                    self.ty_err(ci, si, format!("callee returns {root:?}, declared {declared:?}"));
                }
            }
            Op::While { cond, body } => {
                if nops != 1 {
                    self.ty_err(ci, si, format!("while takes 1 operand, got {nops}"));
                    return;
                }
                let state = self.oshape(ci, si, 0).clone();
                if declared != state {
                    self.ty_err(ci, si, "while result shape != state shape".into());
                }
                for (t, label) in [(*cond, "condition"), (*body, "body")] {
                    let params = self.param_shapes(t);
                    if params.len() != 1 {
                        self.ty_err(ci, si, format!("{label} must take 1 parameter"));
                        continue;
                    }
                    if let Some(p) = &params[0] {
                        if *p != state {
                            self.ty_err(
                                ci,
                                si,
                                format!("{label} parameter {p:?} != state {state:?}"),
                            );
                        }
                    }
                }
                let cr = self.root_shape(*cond);
                if cr != (Shape::Array { ty: ElemType::Pred, dims: vec![] }) {
                    self.ty_err(ci, si, format!("condition returns {cr:?}, want pred[]"));
                }
                let br = self.root_shape(*body);
                if br != state {
                    self.ty_err(ci, si, format!("body returns {br:?}, state is {state:?}"));
                }
            }
            Op::Iota { dim } => {
                let Some((ty, dims)) = decl_arr else {
                    return self.ty_err(ci, si, "iota result must be an array".into());
                };
                if *dim >= dims.len() {
                    self.ty_err(ci, si, format!("iota dimension {dim} >= rank {}", dims.len()));
                }
                if ty == ElemType::Pred {
                    self.ty_err(ci, si, "iota cannot produce pred".into());
                }
            }
            Op::Broadcast { dims: mapping } => {
                let Some((ity, idims)) = self.oarr(ci, si, 0) else { return };
                let Some((oty, odims)) = decl_arr else {
                    return self.ty_err(ci, si, "broadcast result must be an array".into());
                };
                if ity != oty {
                    self.ty_err(ci, si, format!("broadcast {} to {}", ity.name(), oty.name()));
                }
                if mapping.len() != idims.len() {
                    return self.ty_err(
                        ci,
                        si,
                        format!(
                            "broadcast maps {} dims of a rank-{} operand",
                            mapping.len(),
                            idims.len()
                        ),
                    );
                }
                for (k, &d) in mapping.iter().enumerate() {
                    if d >= odims.len() || odims[d] != idims[k] {
                        self.ty_err(
                            ci,
                            si,
                            format!("broadcast operand dim {k} does not land on output dim {d}"),
                        );
                    }
                }
            }
            Op::Reshape => {
                let Some((ity, idims)) = self.oarr(ci, si, 0) else { return };
                let Some((oty, odims)) = decl_arr else {
                    return self.ty_err(ci, si, "reshape result must be an array".into());
                };
                if ity != oty
                    || idims.iter().product::<usize>() != odims.iter().product::<usize>()
                {
                    self.ty_err(
                        ci,
                        si,
                        format!("reshape {}{idims:?} to {}{odims:?}", ity.name(), oty.name()),
                    );
                }
            }
            Op::Transpose { perm } => {
                let Some((ity, idims)) = self.oarr(ci, si, 0) else { return };
                let mut sorted = perm.clone();
                sorted.sort_unstable();
                if sorted != (0..idims.len()).collect::<Vec<_>>() {
                    return self.ty_err(
                        ci,
                        si,
                        format!("transpose {perm:?} is not a permutation of rank {}", idims.len()),
                    );
                }
                let want: Vec<usize> = perm.iter().map(|&p| idims[p]).collect();
                if decl_arr != Some((ity, want.clone())) {
                    self.ty_err(ci, si, format!("transpose produces {}{want:?}", ity.name()));
                }
            }
            Op::Slice { spec } => {
                let Some((ity, idims)) = self.oarr(ci, si, 0) else { return };
                if spec.len() != idims.len() {
                    return self.ty_err(ci, si, "slice spec rank mismatch".into());
                }
                let mut want = Vec::with_capacity(spec.len());
                for (d, &(s, l, st)) in spec.iter().enumerate() {
                    if st == 0 || s > l || l > idims[d] {
                        return self.ty_err(
                            ci,
                            si,
                            format!("slice bounds [{s}:{l}:{st}] invalid for dim {d}"),
                        );
                    }
                    want.push((l - s).div_ceil(st));
                }
                if decl_arr != Some((ity, want.clone())) {
                    self.ty_err(ci, si, format!("slice produces {}{want:?}", ity.name()));
                }
            }
            Op::Concatenate { dim } => {
                if nops == 0 {
                    return self.ty_err(ci, si, "concatenate of nothing".into());
                }
                let Some((ty0, dims0)) = self.oarr(ci, si, 0) else { return };
                if *dim >= dims0.len() {
                    return self.ty_err(ci, si, format!("concatenate dim {dim} out of range"));
                }
                let mut want = dims0.clone();
                want[*dim] = 0;
                for k in 0..nops {
                    let Some((ty, dims)) = self.oarr(ci, si, k) else { return };
                    let same_other = dims.len() == dims0.len()
                        && dims
                            .iter()
                            .enumerate()
                            .all(|(d, &v)| d == *dim || v == dims0[d]);
                    if ty != ty0 || !same_other {
                        return self.ty_err(
                            ci,
                            si,
                            format!("concatenate operand {k} shape/dtype mismatch"),
                        );
                    }
                    want[*dim] += dims[*dim];
                }
                if decl_arr != Some((ty0, want.clone())) {
                    self.ty_err(ci, si, format!("concatenate produces {}{want:?}", ty0.name()));
                }
            }
            Op::Select => {
                let (Some((pty, pdims)), Some(t), Some(f)) =
                    (self.oarr(ci, si, 0), self.oarr(ci, si, 1), self.oarr(ci, si, 2))
                else {
                    return;
                };
                if pty != ElemType::Pred {
                    self.ty_err(ci, si, "select predicate must be pred".into());
                }
                if t != f || pdims != t.1 {
                    self.ty_err(ci, si, "select operand shapes disagree".into());
                }
                if decl_arr != Some(t) {
                    self.ty_err(ci, si, "select result != branch shape".into());
                }
            }
            Op::Compare { .. } => {
                let (Some(a), Some(b)) = (self.oarr(ci, si, 0), self.oarr(ci, si, 1)) else {
                    return;
                };
                if a != b {
                    self.ty_err(ci, si, "compare operand shapes disagree".into());
                }
                if decl_arr != Some((ElemType::Pred, a.1)) {
                    self.ty_err(ci, si, "compare result must be pred of operand dims".into());
                }
            }
            Op::Convert | Op::BitcastConvert => {
                let Some((_, idims)) = self.oarr(ci, si, 0) else { return };
                match decl_arr {
                    Some((_, odims)) if odims == idims => {}
                    _ => self.ty_err(ci, si, "convert must preserve dims".into()),
                }
            }
            Op::Unary(_) => {
                let Some(a) = self.oarr(ci, si, 0) else { return };
                if decl_arr != Some(a) {
                    self.ty_err(ci, si, "unary result != operand shape".into());
                }
            }
            Op::Binary(_) => {
                let (Some(a), Some(b)) = (self.oarr(ci, si, 0), self.oarr(ci, si, 1)) else {
                    return;
                };
                if a != b {
                    // HLO has no implicit broadcast
                    self.ty_err(ci, si, "binary operand shapes disagree".into());
                }
                if decl_arr != Some(a) {
                    self.ty_err(ci, si, "binary result != operand shape".into());
                }
            }
            Op::Dot(nums) => {
                let (Some((lty, ld)), Some((rty, rd))) =
                    (self.oarr(ci, si, 0), self.oarr(ci, si, 1))
                else {
                    return;
                };
                if lty != ElemType::F32 || rty != ElemType::F32 {
                    self.ty_err(ci, si, "dot is f32-only in this backend".into());
                }
                if nums.lhs_batch.len() != nums.rhs_batch.len()
                    || nums.lhs_contracting.len() != nums.rhs_contracting.len()
                {
                    return self.ty_err(ci, si, "dot dimension-number arity mismatch".into());
                }
                let in_range = |ds: &[usize], rank: usize| ds.iter().all(|&d| d < rank);
                if !in_range(&nums.lhs_batch, ld.len())
                    || !in_range(&nums.lhs_contracting, ld.len())
                    || !in_range(&nums.rhs_batch, rd.len())
                    || !in_range(&nums.rhs_contracting, rd.len())
                {
                    return self.ty_err(ci, si, "dot dimension number out of range".into());
                }
                for (t, &d) in nums.lhs_batch.iter().enumerate() {
                    if rd[nums.rhs_batch[t]] != ld[d] {
                        self.ty_err(ci, si, format!("dot batch dim {t} disagrees"));
                    }
                }
                for (t, &d) in nums.lhs_contracting.iter().enumerate() {
                    if rd[nums.rhs_contracting[t]] != ld[d] {
                        self.ty_err(ci, si, format!("dot contracting dim {t} disagrees"));
                    }
                }
                let lfree: Vec<usize> = (0..ld.len())
                    .filter(|d| !nums.lhs_batch.contains(d) && !nums.lhs_contracting.contains(d))
                    .collect();
                let rfree: Vec<usize> = (0..rd.len())
                    .filter(|d| !nums.rhs_batch.contains(d) && !nums.rhs_contracting.contains(d))
                    .collect();
                let mut want: Vec<usize> = nums.lhs_batch.iter().map(|&d| ld[d]).collect();
                want.extend(lfree.iter().map(|&d| ld[d]));
                want.extend(rfree.iter().map(|&d| rd[d]));
                if decl_arr != Some((ElemType::F32, want.clone())) {
                    self.ty_err(ci, si, format!("dot produces f32{want:?}"));
                }
            }
            Op::Gather(g) => {
                let (Some((oty, odims)), Some((sty, sdims_full))) =
                    (self.oarr(ci, si, 0), self.oarr(ci, si, 1))
                else {
                    return;
                };
                if !matches!(sty, ElemType::S32 | ElemType::U32) {
                    self.ty_err(ci, si, "gather indices must be integer".into());
                }
                let Some((dty, ddims)) = decl_arr else {
                    return self.ty_err(ci, si, "gather result must be an array".into());
                };
                if dty != oty {
                    self.ty_err(ci, si, "gather result dtype != operand dtype".into());
                }
                let orank = odims.len();
                if g.slice_sizes.len() != orank
                    || g.start_index_map.iter().any(|&d| d >= orank)
                    || g.index_vector_dim > sdims_full.len()
                {
                    return self.ty_err(ci, si, "gather dimension numbers out of range".into());
                }
                for (d, &sz) in g.slice_sizes.iter().enumerate() {
                    if sz > odims[d] {
                        self.ty_err(
                            ci,
                            si,
                            format!("gather slice_sizes[{d}] = {sz} exceeds operand dim"),
                        );
                    }
                }
                // start-index dims excluding index_vector_dim, in order
                let sdims: Vec<usize> =
                    (0..sdims_full.len()).filter(|&d| d != g.index_vector_dim).collect();
                let batch_out: Vec<usize> =
                    (0..ddims.len()).filter(|d| !g.offset_dims.contains(d)).collect();
                let off_operand: Vec<usize> = (0..orank)
                    .filter(|d| {
                        !g.collapsed_slice_dims.contains(d)
                            && !g.operand_batching_dims.contains(d)
                    })
                    .collect();
                if off_operand.len() != g.offset_dims.len() || batch_out.len() != sdims.len() {
                    return self.ty_err(
                        ci,
                        si,
                        "gather offset/batch dimension arity mismatch".into(),
                    );
                }
                for (j, &sd) in sdims.iter().enumerate() {
                    if ddims[batch_out[j]] != sdims_full[sd] {
                        self.ty_err(
                            ci,
                            si,
                            format!("gather output batch dim {} disagrees", batch_out[j]),
                        );
                    }
                }
                for (k, &od) in off_operand.iter().enumerate() {
                    if ddims[g.offset_dims[k]] != g.slice_sizes[od] {
                        self.ty_err(
                            ci,
                            si,
                            format!("gather output offset dim {} disagrees", g.offset_dims[k]),
                        );
                    }
                }
            }
            Op::Reduce { dims, comp: t } => {
                if nops < 2 || nops % 2 != 0 {
                    return self.ty_err(
                        ci,
                        si,
                        format!("reduce needs N inputs + N inits, got {nops} operands"),
                    );
                }
                let nin = nops / 2;
                let mut itys = Vec::with_capacity(nin);
                let Some((_, xdims)) = self.oarr(ci, si, 0) else { return };
                for k in 0..nin {
                    let Some((ty, dims_k)) = self.oarr(ci, si, k) else { return };
                    if dims_k != xdims {
                        self.ty_err(ci, si, format!("reduce input {k} shape mismatch"));
                    }
                    let Some((init_ty, init_dims)) = self.oarr(ci, si, nin + k) else { return };
                    if !init_dims.is_empty() || init_ty != ty {
                        self.ty_err(ci, si, format!("reduce init {k} must be a {} scalar",
                            ty.name()));
                    }
                    itys.push(ty);
                }
                let mut seen = vec![false; xdims.len()];
                for &d in dims {
                    if d >= xdims.len() || std::mem::replace(&mut seen[d], true) {
                        return self.ty_err(ci, si, format!("reduce dimension {d} invalid"));
                    }
                }
                let kept: Vec<usize> =
                    (0..xdims.len()).filter(|d| !dims.contains(d)).map(|d| xdims[d]).collect();
                let want_elems: Vec<Shape> = itys
                    .iter()
                    .map(|&ty| Shape::Array { ty, dims: kept.clone() })
                    .collect();
                let matches = match &declared {
                    Shape::Tuple(ts) => *ts == want_elems,
                    Shape::Array { .. } => nin == 1 && declared == want_elems[0],
                };
                if !matches {
                    self.ty_err(ci, si, "reduce result shape disagrees".into());
                }
                // region: nin acc scalars then nin elem scalars, root
                // of nin scalars with the acc types
                let params = self.param_shapes(*t);
                if params.len() != nops {
                    self.ty_err(
                        ci,
                        si,
                        format!("reduce region takes {} params, want {nops}", params.len()),
                    );
                } else {
                    for (k, p) in params.iter().enumerate() {
                        let want_ty = itys[k % nin];
                        match p {
                            Some(Shape::Array { ty, dims }) if dims.is_empty() && *ty == want_ty => {
                            }
                            Some(_) => self.ty_err(
                                ci,
                                si,
                                format!("reduce region param {k} must be a {} scalar",
                                    want_ty.name()),
                            ),
                            None => {}
                        }
                    }
                }
                let scalars: Vec<Shape> = itys
                    .iter()
                    .map(|&ty| Shape::Array { ty, dims: vec![] })
                    .collect();
                let root = self.root_shape(*t);
                let root_ok = match &root {
                    Shape::Tuple(ts) => *ts == scalars,
                    Shape::Array { .. } => nin == 1 && root == scalars[0],
                };
                if !root_ok {
                    self.ty_err(ci, si, "reduce region must return the accumulator scalars".into());
                }
            }
            Op::Scatter { comp: t, .. } => {
                if nops != 3 {
                    return self.ty_err(ci, si, format!("scatter takes 3 operands, got {nops}"));
                }
                let (Some((oty, odims)), Some((ity, _)), Some((uty, _))) = (
                    self.oarr(ci, si, 0),
                    self.oarr(ci, si, 1),
                    self.oarr(ci, si, 2),
                ) else {
                    return;
                };
                if !matches!(ity, ElemType::S32 | ElemType::U32) {
                    self.ty_err(ci, si, "scatter indices must be integer".into());
                }
                if uty != oty {
                    self.ty_err(ci, si, "scatter updates dtype != operand dtype".into());
                }
                if decl_arr != Some((oty, odims)) {
                    self.ty_err(ci, si, "scatter result != operand shape".into());
                }
                let params = self.param_shapes(*t);
                let scalar = Shape::Array { ty: oty, dims: vec![] };
                if params.len() != 2
                    || params.iter().flatten().any(|p| *p != scalar)
                    || self.root_shape(*t) != scalar
                {
                    self.ty_err(
                        ci,
                        si,
                        format!("scatter region must be ({n}, {n}) -> {n}", n = oty.name()),
                    );
                }
            }
            Op::Convolution(d) => {
                let (Some((lty, ld)), Some((rty, rd))) =
                    (self.oarr(ci, si, 0), self.oarr(ci, si, 1))
                else {
                    return;
                };
                if lty != ElemType::F32 || rty != ElemType::F32 {
                    self.ty_err(ci, si, "convolution is f32-only in this backend".into());
                }
                let nsp = d.window.len();
                if d.lhs_spatial.len() != nsp
                    || d.rhs_spatial.len() != nsp
                    || d.out_spatial.len() != nsp
                {
                    return self.ty_err(
                        ci,
                        si,
                        "convolution window/spatial-dim arity mismatch".into(),
                    );
                }
                if ld.len() != nsp + 2 || rd.len() != nsp + 2 {
                    return self.ty_err(
                        ci,
                        si,
                        format!("convolution operands must be rank {}", nsp + 2),
                    );
                }
                let in_range = |ds: &[usize], rank: usize| ds.iter().all(|&x| x < rank);
                if d.lhs_batch >= ld.len()
                    || d.lhs_feature >= ld.len()
                    || !in_range(&d.lhs_spatial, ld.len())
                    || d.rhs_input >= rd.len()
                    || d.rhs_output >= rd.len()
                    || !in_range(&d.rhs_spatial, rd.len())
                {
                    return self.ty_err(
                        ci,
                        si,
                        "convolution dimension number out of range".into(),
                    );
                }
                let (fg, bg) = (d.feature_groups, d.batch_groups);
                if fg == 0 || bg == 0 {
                    return self.ty_err(ci, si, "convolution group count must be positive".into());
                }
                let (lb, i_size, o_size) = (ld[d.lhs_batch], rd[d.rhs_input], rd[d.rhs_output]);
                if o_size % fg != 0 || o_size % bg != 0 || lb % bg != 0 {
                    self.ty_err(
                        ci,
                        si,
                        "convolution group counts do not divide the feature/batch dims".into(),
                    );
                }
                if ld[d.lhs_feature] != i_size * fg {
                    self.ty_err(
                        ci,
                        si,
                        format!(
                            "lhs feature dim {} != kernel input {i_size} x {fg} feature groups",
                            ld[d.lhs_feature]
                        ),
                    );
                }
                for (s, w) in d.window.iter().enumerate() {
                    if rd[d.rhs_spatial[s]] != w.size {
                        self.ty_err(
                            ci,
                            si,
                            format!("kernel spatial dim {s} disagrees with window size"),
                        );
                    }
                }
                let Some((_, odims)) = &decl_arr else {
                    return self.ty_err(ci, si, "convolution result must be an array".into());
                };
                if d.out_batch >= odims.len()
                    || d.out_feature >= odims.len()
                    || !in_range(&d.out_spatial, odims.len())
                {
                    return self.ty_err(
                        ci,
                        si,
                        "convolution output dimension number out of range".into(),
                    );
                }
                let mut want = vec![0usize; nsp + 2];
                want[d.out_batch] = lb / bg;
                want[d.out_feature] = o_size;
                for (s, w) in d.window.iter().enumerate() {
                    want[d.out_spatial[s]] = w.out_size(ld[d.lhs_spatial[s]]);
                }
                if decl_arr != Some((ElemType::F32, want.clone())) {
                    self.ty_err(ci, si, format!("convolution produces f32{want:?}"));
                }
            }
            Op::Reverse { dims } => {
                let Some((ity, idims)) = self.oarr(ci, si, 0) else { return };
                let mut seen = vec![false; idims.len()];
                for &dd in dims {
                    if dd >= idims.len() || std::mem::replace(&mut seen[dd], true) {
                        return self.ty_err(ci, si, format!("reverse dimension {dd} invalid"));
                    }
                }
                if decl_arr != Some((ity, idims)) {
                    self.ty_err(ci, si, "reverse result != operand shape".into());
                }
            }
            Op::ReduceWindow { window, comp: t } => {
                let (Some((xty, xdims)), Some((init_ty, init_dims))) =
                    (self.oarr(ci, si, 0), self.oarr(ci, si, 1))
                else {
                    return;
                };
                if window.len() != xdims.len() {
                    return self.ty_err(
                        ci,
                        si,
                        format!(
                            "window has {} dims, operand rank {}",
                            window.len(),
                            xdims.len()
                        ),
                    );
                }
                if init_ty != xty || !init_dims.is_empty() {
                    self.ty_err(
                        ci,
                        si,
                        format!("reduce-window init must be a {} scalar", xty.name()),
                    );
                }
                let want: Vec<usize> =
                    window.iter().zip(&xdims).map(|(w, &n)| w.out_size(n)).collect();
                if decl_arr != Some((xty, want.clone())) {
                    self.ty_err(ci, si, format!("reduce-window produces {}{want:?}", xty.name()));
                }
                // region: (acc, elem) scalars -> acc scalar
                let params = self.param_shapes(*t);
                let scalar = Shape::Array { ty: xty, dims: vec![] };
                if params.len() != 2
                    || params.iter().flatten().any(|p| *p != scalar)
                    || self.root_shape(*t) != scalar
                {
                    self.ty_err(
                        ci,
                        si,
                        format!("reduce-window region must be ({n}, {n}) -> {n}", n = xty.name()),
                    );
                }
            }
        }
    }

    /// Declared parameter shapes of computation `t` (None where the
    /// parameter never appears).
    fn param_shapes(&self, t: usize) -> Vec<Option<Shape>> {
        let c = &self.plan.comps[t];
        let mut out = vec![None; c.n_params];
        for ins in &c.instrs {
            if let Op::Parameter(i) = &ins.op {
                if *i < c.n_params {
                    out[*i] = Some(ins.shape.clone());
                }
            }
        }
        out
    }

    fn root_shape(&self, t: usize) -> Shape {
        let c = &self.plan.comps[t];
        c.instrs[c.root].shape.clone()
    }

    // ---------------------------------------------------- fusion check ---

    /// Re-prove each `Fused` annotation from the instructions it
    /// covers, with matchers authored independently of `fuse.rs`.
    fn check_fusion(&mut self, ci: usize, si: usize) {
        let comp = &self.plan.comps[ci];
        let ins = &comp.instrs[si];
        match (&comp.fused[si], &ins.op) {
            (Fused::None, _) => {}
            (Fused::Bin { op, acc_first }, Op::Reduce { comp: t, .. }) => {
                if ins.operands.len() != 2 || !matches!(ins.shape, Shape::Array { .. }) {
                    self.diag(
                        ci,
                        si,
                        DiagKind::Fusion,
                        "fused reduce must be single-input with an array result".into(),
                    );
                } else if let Err(msg) = self.prove_bin_region(*t, *op, *acc_first) {
                    self.diag(ci, si, DiagKind::Fusion, msg);
                }
            }
            (Fused::Bin { op, acc_first }, Op::Scatter { comp: t, .. }) => {
                if ins.operands.len() != 3 {
                    self.diag(
                        ci,
                        si,
                        DiagKind::Fusion,
                        "fused scatter must have 3 operands".into(),
                    );
                } else if let Err(msg) = self.prove_bin_region(*t, *op, *acc_first) {
                    self.diag(ci, si, DiagKind::Fusion, msg);
                }
            }
            (Fused::Bin { op, acc_first }, Op::ReduceWindow { comp: t, .. }) => {
                if ins.operands.len() != 2 || !matches!(ins.shape, Shape::Array { .. }) {
                    self.diag(
                        ci,
                        si,
                        DiagKind::Fusion,
                        "fused reduce-window must be single-input with an array result".into(),
                    );
                } else if let Err(msg) = self.prove_bin_region(*t, *op, *acc_first) {
                    self.diag(ci, si, DiagKind::Fusion, msg);
                }
            }
            (Fused::Counted(spec), Op::While { cond, body }) => {
                match self.derive_counted(*cond, *body) {
                    Ok(want) if want == **spec => {}
                    Ok(want) => self.diag(
                        ci,
                        si,
                        DiagKind::Fusion,
                        format!("counted-loop spec disagrees with re-derivation ({want:?})"),
                    ),
                    Err(msg) => self.diag(
                        ci,
                        si,
                        DiagKind::Fusion,
                        format!("counted-loop preconditions do not hold: {msg}"),
                    ),
                }
            }
            (Fused::Threefry, Op::Call { comp: t }) => {
                if let Err(msg) = self.prove_threefry(*t) {
                    self.diag(
                        ci,
                        si,
                        DiagKind::Fusion,
                        format!("threefry preconditions do not hold: {msg}"),
                    );
                }
            }
            (Fused::Chain(spec), _) => {
                if let Err(msg) = self.prove_chain(ci, si, spec) {
                    self.diag(
                        ci,
                        si,
                        DiagKind::Fusion,
                        format!("chain preconditions do not hold: {msg}"),
                    );
                }
            }
            (Fused::ChainInterior { root }, _) => {
                // the root-side re-proof validates the whole membership;
                // here only the back-pointer itself: it must name a
                // chain in this computation that claims this step
                let claimed = comp.fused.get(*root).is_some_and(
                    |f| matches!(f, Fused::Chain(spec) if spec.steps.contains(&si)),
                );
                if !claimed {
                    self.diag(
                        ci,
                        si,
                        DiagKind::Fusion,
                        format!(
                            "chain-interior marker names step {root}, which is not a chain \
                             claiming this step"
                        ),
                    );
                }
            }
            (fused, _) => {
                self.diag(
                    ci,
                    si,
                    DiagKind::Fusion,
                    format!("{fused:?} annotation on an incompatible op"),
                );
            }
        }
    }

    // ------------------------------------------------ shard safety ---

    /// Every step that can dispatch a kernel that shards under the
    /// `threads` knob must name a kernel declared in the registry with
    /// its determinism argument.
    fn check_shard(&mut self, ci: usize, si: usize) {
        let comp = &self.plan.comps[ci];
        let ins = &comp.instrs[si];
        if let Some(kernel) = sharding_kernel(ins, &comp.fused[si]) {
            if !self.registry.iter().any(|e| e.name == kernel) {
                self.diag(
                    ci,
                    si,
                    DiagKind::ShardSafety,
                    format!(
                        "sharding kernel {kernel} is not declared in the shard-safety \
                         registry (declare it with its determinism argument)"
                    ),
                );
            }
        }
    }

    /// Prove the region is exactly `{p0, p1, ROOT bin(p0, p1)}` with
    /// the claimed op and operand order.
    fn prove_bin_region(&self, t: usize, op: BinaryOp, acc_first: bool) -> Result<(), String> {
        let c = &self.plan.comps[t];
        if c.instrs.len() != 3 || c.n_params != 2 {
            return Err("region is not a three-instruction two-parameter body".into());
        }
        let mut param_at = [None; 2];
        for (i, ins) in c.instrs.iter().enumerate() {
            if let Op::Parameter(k) = ins.op {
                if k < 2 {
                    param_at[k] = Some(i);
                }
            }
        }
        let (Some(p0), Some(p1)) = (param_at[0], param_at[1]) else {
            return Err("region is missing a parameter".into());
        };
        let root = &c.instrs[c.root];
        let Op::Binary(got) = root.op else {
            return Err("region root is not a binary op".into());
        };
        if got != op {
            return Err(format!("region computes {got:?}, annotation claims {op:?}"));
        }
        let want = if acc_first { [p0, p1] } else { [p1, p0] };
        if root.operands != want {
            return Err("region operand order disagrees with acc_first".into());
        }
        Ok(())
    }

    // -------------------------------------------------- chain re-proof ---

    /// Re-prove one elementwise-chain superinstruction from scratch.
    /// The claimed membership (`spec.steps`) is taken as the planner's
    /// policy choice; everything that makes it *sound* is re-derived
    /// here with its own forward walk (not `fuse::match_chains`'s
    /// descending cone growth) and must agree exactly:
    ///
    /// * membership is a bijection with the `ChainInterior` markers;
    /// * every elided register is unobservable — exactly one reader,
    ///   inside the chain, and never the computation root (an elided
    ///   register is never written);
    /// * members are elementwise steps of the chain's shape, or
    ///   broadcast splats of a one-element source living outside the
    ///   chain;
    /// * the slot assignment (inputs in first-reference order, one
    ///   tape slot per elementwise member in program order) and the
    ///   compiled tape match the re-derivation;
    /// * take flags match this module's own effective liveness, and
    ///   the in-place slot is the canonical first consumable full slot
    ///   whose register matches the output exactly.
    fn prove_chain(&self, ci: usize, si: usize, spec: &ChainSpec) -> Result<(), String> {
        let comp = &self.plan.comps[ci];
        let n = comp.instrs.len();
        let (oty, odims) = match &comp.instrs[si].shape {
            Shape::Array { ty, dims } => (*ty, dims.clone()),
            Shape::Tuple(_) => return Err("chain root result is a tuple".into()),
        };
        let dims_of = |s: usize| {
            comp.instrs[s].shape.array().ok().map(|(_, d)| d.to_vec())
        };

        // membership must be a bijection with the interior markers
        let mut member = vec![false; n];
        let mut prev = None;
        for &s in &spec.steps {
            if s >= si {
                return Err(format!("claimed step {s} does not precede the root"));
            }
            if prev.is_some_and(|p: usize| p >= s) {
                return Err("claimed steps are not strictly ascending".into());
            }
            prev = Some(s);
            if !matches!(comp.fused[s], Fused::ChainInterior { root } if root == si) {
                return Err(format!("claimed step {s} is not marked as this chain's interior"));
            }
            member[s] = true;
        }
        for (s, f) in comp.fused.iter().enumerate() {
            if matches!(f, Fused::ChainInterior { root } if *root == si) && !member[s] {
                return Err(format!("step {s} carries this chain's interior marker but is not claimed"));
            }
        }
        member[si] = true;

        // elided registers must be unobservable outside the chain
        let mut readers = vec![0usize; n];
        for ins in &comp.instrs {
            for &o in &ins.operands {
                if o < n {
                    readers[o] += 1;
                }
            }
        }
        for &s in &spec.steps {
            if s == comp.root {
                return Err(format!(
                    "claimed step {s} is the computation root; eliding it would drop the result"
                ));
            }
            if readers[s] != 1 {
                return Err(format!(
                    "elided step {s} has {} readers, want exactly one",
                    readers[s]
                ));
            }
        }

        // classify members: elementwise steps of the chain shape join
        // the tape in program order; broadcasts are splat elisions
        let elementwise = |s: usize| {
            matches!(
                comp.instrs[s].op,
                Op::Unary(_) | Op::Binary(_) | Op::Select | Op::Compare { .. } | Op::Convert
            )
        };
        let mut tape_members: Vec<usize> = Vec::new();
        for &s in spec.steps.iter().chain(std::iter::once(&si)) {
            if elementwise(s) {
                if dims_of(s) != Some(odims.clone()) {
                    return Err(format!("member {s} does not produce the chain shape"));
                }
                tape_members.push(s);
            } else if s == si || !matches!(comp.instrs[s].op, Op::Broadcast { .. }) {
                return Err(format!(
                    "step {s} is neither an elementwise op nor a broadcast splat"
                ));
            }
        }

        // re-derive the slot assignment with a forward walk: external
        // inputs in first-reference order, then one tape slot per
        // elementwise member
        let mut tape_pos = vec![usize::MAX; n];
        for (t, &s) in tape_members.iter().enumerate() {
            tape_pos[s] = t;
        }
        let mut inputs: Vec<ChainInput> = Vec::new();
        let mut input_pos = vec![usize::MAX; n];
        let mut read_in_chain = vec![false; n];
        for &s in &tape_members {
            for &o in &comp.instrs[s].operands {
                read_in_chain[o] = true;
                if tape_pos[o] != usize::MAX || input_pos[o] != usize::MAX {
                    continue; // a tape member, or already assigned
                }
                input_pos[o] = inputs.len();
                if member[o] {
                    // a claimed broadcast splat: one one-element source
                    // living outside the chain, broadcast to its shape
                    let b = &comp.instrs[o];
                    let &[src] = b.operands.as_slice() else {
                        return Err(format!("broadcast splat {o} must have one operand"));
                    };
                    if dims_of(o) != Some(odims.clone()) {
                        return Err(format!(
                            "broadcast splat {o} does not produce the chain shape"
                        ));
                    }
                    if comp.instrs[src].shape.numel() != 1 {
                        return Err(format!("broadcast splat {o}'s source is not one element"));
                    }
                    if member[src] {
                        return Err(format!(
                            "broadcast splat {o}'s source {src} is elided and never written"
                        ));
                    }
                    inputs.push(ChainInput::Scalar(src));
                } else {
                    if dims_of(o) != Some(odims.clone()) {
                        return Err(format!("input register {o} does not have the chain shape"));
                    }
                    inputs.push(ChainInput::Full(o));
                }
            }
        }
        for &s in &spec.steps {
            if !read_in_chain[s] {
                return Err(format!("claimed step {s} is never read inside the chain"));
            }
        }

        // re-derive the tape
        let n_in = inputs.len();
        if n_in + tape_members.len() > u16::MAX as usize {
            return Err("chain slot count overflows the tape encoding".into());
        }
        let slot = |o: usize| -> u16 {
            if tape_pos[o] != usize::MAX {
                (n_in + tape_pos[o]) as u16
            } else {
                input_pos[o] as u16
            }
        };
        let mut tape: Vec<TapeOp> = Vec::with_capacity(tape_members.len());
        for &s in &tape_members {
            let ins = &comp.instrs[s];
            let mty = ins.shape.array().map(|(t, _)| t).map_err(|e| e.to_string())?;
            let sty = |k: usize| -> Result<ElemType, String> {
                comp.instrs[ins.operands[k]]
                    .shape
                    .array()
                    .map(|(t, _)| t)
                    .map_err(|_| format!("member {s}'s operand {k} is a tuple"))
            };
            let t = match (&ins.op, ins.operands.as_slice()) {
                (Op::Unary(u), &[a]) => TapeOp::Unary { op: *u, ty: mty, a: slot(a) },
                (Op::Binary(bo), &[a, b]) => {
                    TapeOp::Binary { op: *bo, ty: mty, a: slot(a), b: slot(b) }
                }
                (Op::Compare { dir }, &[a, b]) => {
                    TapeOp::Compare { dir: *dir, ty: sty(0)?, a: slot(a), b: slot(b) }
                }
                (Op::Select, &[p, t, f]) => {
                    TapeOp::Select { p: slot(p), t: slot(t), f: slot(f) }
                }
                (Op::Convert, &[a]) => TapeOp::Convert { from: sty(0)?, to: mty, a: slot(a) },
                _ => return Err(format!("member {s} has an unexpected operand count")),
            };
            tape.push(t);
        }

        // take: an input may be consumed iff the chain root is its last
        // *effective* use and it feeds only one slot; in-place: the
        // first consumable full slot matching the output exactly
        let eff = |s: usize| match comp.fused[s] {
            Fused::ChainInterior { root } if root < n => root,
            _ => s,
        };
        let mut last: Vec<Option<usize>> = vec![None; n];
        for (s, ins) in comp.instrs.iter().enumerate() {
            for &o in &ins.operands {
                if o < n {
                    let e = eff(s);
                    last[o] = Some(last[o].map_or(e, |l| l.max(e)));
                }
            }
        }
        let take: Vec<bool> = inputs
            .iter()
            .map(|inp| {
                let r = inp.reg();
                r != comp.root
                    && last[r] == Some(si)
                    && inputs.iter().filter(|i2| i2.reg() == r).count() == 1
            })
            .collect();
        let inplace = inputs.iter().enumerate().find_map(|(i, inp)| match *inp {
            ChainInput::Full(r) if take[i] => comp.instrs[r]
                .shape
                .array()
                .is_ok_and(|(t, d)| t == oty && d == odims)
                .then_some(i),
            _ => None,
        });

        let want =
            ChainSpec { steps: spec.steps.clone(), inputs, take, inplace, tape };
        if *spec != want {
            return Err(format!("chain spec disagrees with re-derivation ({want:?})"));
        }
        Ok(())
    }

    // ------------------------------------------- counted-loop re-proof ---

    /// Derive the counted-loop spec for (cond, body) from scratch.
    /// Mirrors the *invariant* (not the code) of `fuse.rs`: condition
    /// is `state[idx] < const` and the body re-binds `state[idx]` to
    /// `state[idx] + 1`, touching the state parameter only through
    /// `get-tuple-element`.
    fn derive_counted(&self, cond: usize, body: usize) -> Result<CountedLoop, String> {
        let cc = &self.plan.comps[cond];
        let cp = only_param(cc).ok_or("condition must have exactly one parameter")?;
        let croot = &cc.instrs[cc.root];
        if !matches!(croot.op, Op::Compare { dir: CmpDir::Lt }) || croot.operands.len() != 2 {
            return Err("condition root is not an LT compare".into());
        }
        let counter = croot.operands[0];
        let idx = match &cc.instrs[counter].op {
            Op::GetTupleElement(e) if cc.instrs[counter].operands == [cp] => *e,
            _ => return Err("condition does not compare a state element".into()),
        };
        let bound = scalar_int_const(&cc.instrs[croot.operands[1]])
            .ok_or("condition bound is not a scalar integer constant")?;

        let bc = &self.plan.comps[body];
        let bp = only_param(bc).ok_or("body must have exactly one parameter")?;
        let broot = &bc.instrs[bc.root];
        if !matches!(broot.op, Op::Tuple) {
            return Err("body root is not a tuple".into());
        }
        let root_ops = broot.operands.clone();
        let arity = root_ops.len();
        if idx >= arity {
            return Err("counter element index exceeds state arity".into());
        }
        let mut state_reads = Vec::new();
        for (i, ins) in bc.instrs.iter().enumerate() {
            if let Op::GetTupleElement(e) = &ins.op {
                if ins.operands == [bp] {
                    if *e >= arity {
                        return Err("state read out of tuple range".into());
                    }
                    state_reads.push((i, *e));
                    continue;
                }
            }
            if ins.operands.contains(&bp) {
                return Err("body touches the state parameter outside get-tuple-element".into());
            }
        }
        let inc = &bc.instrs[root_ops[idx]];
        if !matches!(inc.op, Op::Binary(BinaryOp::Add)) || inc.operands.len() != 2 {
            return Err("counter is not re-bound by an add".into());
        }
        let reads_counter = |i: usize| state_reads.contains(&(i, idx));
        let lit_one = |i: usize| scalar_int_const(&bc.instrs[i]) == Some(1);
        let (a, b) = (inc.operands[0], inc.operands[1]);
        if !((reads_counter(a) && lit_one(b)) || (reads_counter(b) && lit_one(a))) {
            return Err("counter increment is not counter + 1".into());
        }
        let take_state = state_reads
            .iter()
            .map(|&(_, e)| state_reads.iter().filter(|&&(_, e2)| e2 == e).count() == 1)
            .collect();
        let steps = (0..bc.instrs.len())
            .filter(|&i| {
                i != bp && i != bc.root && !state_reads.iter().any(|&(gi, _)| gi == i)
            })
            .collect();
        Ok(CountedLoop { idx, bound, body, arity, state_reads, take_state, steps, root_ops })
    }

    // ----------------------------------------------- threefry re-proof ---

    /// Re-prove that computation `t` is exactly one jax threefry-2x32
    /// round group, with an expression matcher authored independently
    /// of `fuse.rs` (own tree type, own resolver, own canonical chain).
    fn prove_threefry(&self, t: usize) -> Result<(), String> {
        let c = &self.plan.comps[t];
        if c.n_params != 8 {
            return Err(format!("{} parameters, want 8", c.n_params));
        }
        let mut pshapes: [Option<(ElemType, Vec<usize>)>; 8] = Default::default();
        for ins in &c.instrs {
            if let Op::Parameter(k) = ins.op {
                let Shape::Array { ty, dims } = &ins.shape else {
                    return Err("tuple-shaped parameter".into());
                };
                if k >= 8 || pshapes[k].replace((*ty, dims.clone())).is_some() {
                    return Err("duplicate or out-of-range parameter".into());
                }
            }
        }
        let shapes: Vec<(ElemType, Vec<usize>)> = pshapes
            .into_iter()
            .collect::<Option<Vec<_>>>()
            .ok_or("a parameter never appears")?;
        // canonical signature (i, x0, x1, k0, k1, k2, rot_a, rot_b)
        for (k, want_ty) in
            [(0, ElemType::S32), (3, ElemType::U32), (4, ElemType::U32), (5, ElemType::U32)]
        {
            if shapes[k] != (want_ty, vec![]) {
                return Err(format!("parameter {k} is not a {} scalar", want_ty.name()));
            }
        }
        if shapes[1].0 != ElemType::U32 || shapes[1] != shapes[2] {
            return Err("lane parameters are not matching u32 arrays".into());
        }
        if shapes[6] != (ElemType::U32, vec![4]) || shapes[6] != shapes[7] {
            return Err("rotation parameters are not u32[4]".into());
        }
        let root = &c.instrs[c.root];
        if !matches!(root.op, Op::Tuple) || root.operands.len() != 8 {
            return Err("root is not an eight-element tuple".into());
        }
        // output state permutation (i+1, x0', x1', k1, k2, k0, rot_b,
        // rot_a) — output k must carry the canonical input shape
        let perm = [0usize, 1, 2, 4, 5, 3, 7, 6];
        for (k, &o) in root.operands.iter().enumerate() {
            let Shape::Array { ty, dims } = &c.instrs[o].shape else {
                return Err("tuple-shaped root operand".into());
            };
            if (*ty, dims.clone()) != shapes[perm[k]] {
                return Err(format!("output {k} shape is not the rotated state shape"));
            }
        }
        let mut memo: Vec<Option<Option<TExpr>>> = vec![None; c.instrs.len()];
        let want = round_chain();
        for (k, &o) in root.operands.iter().enumerate() {
            match texpr(&c.instrs, o, &mut memo) {
                Some(e) if e == want[k] => {}
                _ => return Err(format!("output {k} does not match the canonical round chain")),
            }
        }
        Ok(())
    }
}

/// The single `Parameter` instruction index of a one-parameter
/// computation plan.
fn only_param(c: &CompPlan) -> Option<usize> {
    if c.n_params != 1 {
        return None;
    }
    let mut found = None;
    for (i, ins) in c.instrs.iter().enumerate() {
        if matches!(ins.op, Op::Parameter(_)) {
            if found.replace(i).is_some() {
                return None;
            }
        }
    }
    found
}

/// Scalar s32/u32 constant value of an instruction, if it is one.
fn scalar_int_const(ins: &Instr) -> Option<i64> {
    match &ins.op {
        Op::Constant(c) if c.numel() == 1 => match &*c.buf {
            Buf::S32(v) => Some(i64::from(v[0])),
            Buf::U32(v) => Some(i64::from(v[0])),
            _ => None,
        },
        _ => None,
    }
}

/// Symbolic u32 expression for the threefry re-proof. `reshape` and
/// scalar `broadcast` are transparent, a unit slice of a parameter is
/// a lane pick — parallel in *meaning* to `fuse::Ex` (both encode the
/// same canonical chain) but independently authored and resolved.
#[derive(Debug, Clone, PartialEq)]
enum TExpr {
    Param(usize),
    ConstU(u32),
    ConstS(i32),
    /// `parameter(k)[j:j+1]`.
    Lane(usize, usize),
    /// s32 → u32 convert.
    ToU32(Box<TExpr>),
    Bin(BinaryOp, Box<TExpr>, Box<TExpr>),
}

fn texpr(instrs: &[Instr], i: usize, memo: &mut Vec<Option<Option<TExpr>>>) -> Option<TExpr> {
    if let Some(r) = &memo[i] {
        return r.clone();
    }
    let ins = &instrs[i];
    let r: Option<TExpr> = match &ins.op {
        Op::Parameter(k) => Some(TExpr::Param(*k)),
        Op::Constant(c) if c.numel() == 1 => match &*c.buf {
            Buf::U32(v) => Some(TExpr::ConstU(v[0])),
            Buf::S32(v) => Some(TExpr::ConstS(v[0])),
            _ => None,
        },
        Op::Reshape if ins.operands.len() == 1 => texpr(instrs, ins.operands[0], memo),
        Op::Broadcast { .. } if ins.operands.len() == 1 => {
            let o = ins.operands[0];
            if instrs[o].shape.numel() == 1 {
                texpr(instrs, o, memo)
            } else {
                None
            }
        }
        Op::Convert if ins.operands.len() == 1 => {
            let o = ins.operands[0];
            let from = instrs[o].shape.array().map(|(t, _)| t);
            let to = ins.shape.array().map(|(t, _)| t);
            match (from, to) {
                (Ok(ElemType::S32), Ok(ElemType::U32)) => {
                    texpr(instrs, o, memo).map(|e| TExpr::ToU32(Box::new(e)))
                }
                _ => None,
            }
        }
        Op::Slice { spec } if ins.operands.len() == 1 => {
            match (&instrs[ins.operands[0]].op, &spec[..]) {
                (Op::Parameter(k), &[(s, l, 1)]) if l == s + 1 => Some(TExpr::Lane(*k, s)),
                _ => None,
            }
        }
        Op::Binary(
            b @ (BinaryOp::Add
            | BinaryOp::Xor
            | BinaryOp::Or
            | BinaryOp::Sub
            | BinaryOp::Shl
            | BinaryOp::ShrLogical),
        ) if ins.operands.len() == 2 => {
            let x = texpr(instrs, ins.operands[0], memo)?;
            let y = texpr(instrs, ins.operands[1], memo)?;
            Some(TExpr::Bin(*b, Box::new(x), Box::new(y)))
        }
        _ => None,
    };
    memo[i] = Some(r.clone());
    r
}

/// The canonical four-round threefry-2x32 chain: the eight root tuple
/// operands `(i+1, x0', x1', k1, k2, k0, rot_b, rot_a)` in terms of
/// the eight parameters `(i, x0, x1, k0, k1, k2, rot_a, rot_b)`. Must
/// stay in lockstep with `ops::threefry2x32` (the kernel) and
/// `fuse::expected_round` (the planner's matcher) — all three encode
/// the same jax lowering.
fn round_chain() -> [TExpr; 8] {
    use BinaryOp::{Add, Or, Shl, ShrLogical, Sub, Xor};
    fn bin(b: BinaryOp, x: TExpr, y: TExpr) -> TExpr {
        TExpr::Bin(b, Box::new(x), Box::new(y))
    }
    fn rot(x: TExpr, j: usize) -> TExpr {
        bin(
            Or,
            bin(Shl, x.clone(), TExpr::Lane(6, j)),
            bin(ShrLogical, x, bin(Sub, TExpr::ConstU(32), TExpr::Lane(6, j))),
        )
    }
    let mut x0 = bin(Add, TExpr::Param(1), TExpr::Param(2));
    let mut x1 = bin(Xor, x0.clone(), rot(TExpr::Param(2), 0));
    for j in 1..4 {
        let nx0 = bin(Add, x0.clone(), x1.clone());
        x1 = bin(Xor, nx0.clone(), rot(x1, j));
        x0 = nx0;
    }
    let out_i = bin(Add, TExpr::Param(0), TExpr::ConstS(1));
    let out_x0 = bin(Add, x0, TExpr::Param(3));
    let out_x1 = bin(
        Add,
        bin(Add, x1, TExpr::Param(4)),
        TExpr::ToU32(Box::new(out_i.clone())),
    );
    [
        out_i,
        out_x0,
        out_x1,
        TExpr::Param(4),
        TExpr::Param(5),
        TExpr::Param(3),
        TExpr::Param(7),
        TExpr::Param(6),
    ]
}

// -------------------------------------------------------------- census ---

/// Plan-wide statistics printed by `qn lint-plan`: instruction counts
/// per op label, the fusion census, in-place (move) flags and the
/// sharding-kernel population.
#[derive(Debug, Clone, Default)]
pub struct PlanCensus {
    pub comps: usize,
    pub instrs: usize,
    /// Instruction count per executor label (`op_label`).
    pub op_counts: BTreeMap<&'static str, usize>,
    pub fusion: crate::runtime::interp::plan::FusionStats,
    /// Total operand slots across all steps.
    pub operand_slots: usize,
    /// Operand slots flagged as moves (in-place candidates).
    pub move_slots: usize,
    /// Steps per sharding-kernel key ([`sharding_kernel`]).
    pub shard_kernels: BTreeMap<&'static str, usize>,
}

/// Collect the census of a compiled plan.
pub fn census(plan: &Plan) -> PlanCensus {
    let mut c = PlanCensus { comps: plan.comps.len(), fusion: plan.fusion_stats(), ..Default::default() };
    for comp in &plan.comps {
        c.instrs += comp.instrs.len();
        for (si, ins) in comp.instrs.iter().enumerate() {
            let (label, _) = op_label(ins, &comp.fused[si]);
            *c.op_counts.entry(label).or_default() += 1;
            c.operand_slots += ins.operands.len();
            c.move_slots += comp.take[si].iter().filter(|&&t| t).count();
            if let Some(kernel) = sharding_kernel(ins, &comp.fused[si]) {
                *c.shard_kernels.entry(kernel).or_default() += 1;
            }
        }
    }
    c
}

impl fmt::Display for PlanCensus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{} computations, {} instructions", self.comps, self.instrs)?;
        writeln!(
            f,
            "in-place: {} of {} operand slots are moves",
            self.move_slots, self.operand_slots
        )?;
        writeln!(
            f,
            "fusion: {} counted loops, {} generic whiles, {} threefry calls, \
             {} fused reduces, {} fused scatters, {} fused windows, \
             {} chains ({} steps)",
            self.fusion.counted_loops,
            self.fusion.generic_whiles,
            self.fusion.threefry_calls,
            self.fusion.fused_reduces,
            self.fusion.fused_scatters,
            self.fusion.fused_windows,
            self.fusion.fused_chains,
            self.fusion.chain_steps
        )?;
        writeln!(f, "sharding kernels:")?;
        for (name, count) in &self.shard_kernels {
            writeln!(f, "  {name:<24} {count:>6}")?;
        }
        writeln!(f, "instructions by op:")?;
        let mut rows: Vec<(&str, usize)> =
            self.op_counts.iter().map(|(k, v)| (*k, *v)).collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        for (label, count) in rows {
            writeln!(f, "  {label:<24} {count:>6}")?;
        }
        Ok(())
    }
}

// --------------------------------------------------------------- tests ---

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::interp::parser::parse_module;
    use crate::runtime::interp::plan::PlanOptions;

    /// The counted-loop fixture from `fuse.rs`'s tests: state (i, acc),
    /// i < 4, i += 1 — fuses under default options.
    const COUNTED: &str = "HloModule t\n\ncond.1 {\n  s.1 = (s32[], f32[2]) parameter(0)\n  \
        i.2 = s32[] get-tuple-element(s.1), index=0\n  n.3 = s32[] constant(4)\n  \
        ROOT lt.4 = pred[] compare(i.2, n.3), direction=LT\n}\n\nbody.1 {\n  \
        s.1 = (s32[], f32[2]) parameter(0)\n  i.2 = s32[] get-tuple-element(s.1), index=0\n  \
        v.3 = f32[2]{0} get-tuple-element(s.1), index=1\n  one.4 = s32[] constant(1)\n  \
        c.5 = f32[2]{0} constant({0.5, 0.25})\n  i2.6 = s32[] add(i.2, one.4)\n  \
        v2.7 = f32[2]{0} add(v.3, c.5)\n  \
        ROOT t.8 = (s32[], f32[2]) tuple(i2.6, v2.7)\n}\n\nENTRY main.1 {\n  \
        z.1 = s32[] constant(0)\n  v0.2 = f32[2]{0} parameter(0)\n  \
        st.3 = (s32[], f32[2]) tuple(z.1, v0.2)\n  \
        ROOT w.4 = (s32[], f32[2]) while(st.3), condition=cond.1, body=body.1\n}\n";

    /// A small straight-line chain with a dot, reduce and unary —
    /// exercises liveness, types and the shard registry together.
    const CHAIN: &str = "HloModule t\n\nsum.1 {\n  a.1 = f32[] parameter(0)\n  \
        b.2 = f32[] parameter(1)\n  ROOT add.3 = f32[] add(a.1, b.2)\n}\n\n\
        ENTRY main.1 {\n  x.1 = f32[3,4]{1,0} parameter(0)\n  \
        w.2 = f32[4,2]{1,0} parameter(1)\n  \
        d.3 = f32[3,2]{1,0} dot(x.1, w.2), lhs_contracting_dims={1}, \
        rhs_contracting_dims={0}\n  n.4 = f32[3,2]{1,0} negate(d.3)\n  \
        z.5 = f32[] constant(0)\n  \
        ROOT r.6 = f32[2]{0} reduce(n.4, z.5), dimensions={0}, to_apply=sum.1\n}\n";

    /// A tiny conv + max-pool pipeline: exercises the convolution
    /// shape inference, the fused reduce-window and both new shard
    /// kernels.
    const CONV: &str = "HloModule t\n\nmax.1 {\n  a.1 = f32[] parameter(0)\n  \
        b.2 = f32[] parameter(1)\n  ROOT m.3 = f32[] maximum(a.1, b.2)\n}\n\n\
        ENTRY main.1 {\n  x.1 = f32[1,6,6,2]{3,2,1,0} parameter(0)\n  \
        w.2 = f32[3,3,2,4]{3,2,1,0} parameter(1)\n  \
        c.3 = f32[1,6,6,4]{3,2,1,0} convolution(x.1, w.2), \
        window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f\n  \
        z.4 = f32[] constant(0)\n  \
        ROOT p.5 = f32[1,3,3,4]{3,2,1,0} reduce-window(c.3, z.4), \
        window={size=1x2x2x1 stride=1x2x2x1}, to_apply=max.1\n}\n";

    /// The elementwise-chain fixture from `fuse.rs`'s tests: a select
    /// roots a multiply + compare diamond over a shared exp, with a
    /// folded broadcast-of-scalar splat.
    const ECHAIN: &str = "HloModule t\n\nENTRY main.1 {\n  x.1 = f32[4]{0} parameter(0)\n  \
        c.2 = f32[] constant(2)\n  b.3 = f32[4]{0} broadcast(c.2), dimensions={}\n  \
        e.4 = f32[4]{0} exponential(x.1)\n  m.5 = f32[4]{0} multiply(e.4, b.3)\n  \
        p.6 = pred[4]{0} compare(x.1, e.4), direction=LT\n  \
        ROOT s.7 = f32[4]{0} select(p.6, m.5, x.1)\n}\n";

    /// A register whose last *instruction-level* read (the reshape)
    /// precedes its last *effective* read (the chain root that loads
    /// it for the elided negate): the case instruction-level liveness
    /// cannot police.
    const SPLIT: &str = "HloModule t\n\nENTRY main.1 {\n  x.1 = f32[4]{0} parameter(0)\n  \
        c.2 = f32[4]{0} constant({1, 2, 3, 4})\n  n.3 = f32[4]{0} negate(x.1)\n  \
        r.4 = f32[1,4]{1,0} reshape(x.1)\n  a.5 = f32[4]{0} add(n.3, c.2)\n  \
        ROOT t.6 = (f32[4], f32[1,4]) tuple(a.5, r.4)\n}\n";

    fn compile(text: &str) -> Plan {
        Plan::compile_unverified(&parse_module(text).unwrap(), PlanOptions::default())
    }

    /// Compile the chain fixture and locate its `Fused::Chain` root.
    fn chain_plan() -> (Plan, usize) {
        let plan = compile(ECHAIN);
        let e = plan.entry;
        let ri = plan.comps[e]
            .fused
            .iter()
            .position(|f| matches!(f, Fused::Chain(_)))
            .expect("the select must root a chain");
        (plan, ri)
    }

    fn kinds(diags: &[Diagnostic]) -> Vec<DiagKind> {
        diags.iter().map(|d| d.kind).collect()
    }

    #[test]
    fn verification_is_on_in_tests() {
        // cargo test builds with debug assertions: every compiled plan
        // in the suite runs through the verifier
        assert!(should_verify());
    }

    #[test]
    fn clean_plans_verify_clean_at_every_option() {
        for text in [COUNTED, CHAIN, CONV, ECHAIN, SPLIT] {
            let m = parse_module(text).unwrap();
            for bits in 0u8..8 {
                let (cl, tf, ch) = (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0);
                let opts = PlanOptions { counted_loops: cl, threefry: tf, chains: ch };
                let plan = Plan::compile_unverified(&m, opts);
                let diags = verify(&plan);
                assert!(diags.is_empty(), "cl={cl} tf={tf} ch={ch}:\n{}", render(&diags));
            }
        }
    }

    #[test]
    fn early_free_is_a_stale_read() {
        let mut plan = compile(CHAIN);
        let e = plan.entry;
        // free the dot result right after it is computed; negate (#3)
        // still reads it
        plan.comps[e].free_after[2].push(2);
        let diags = verify(&plan);
        assert!(kinds(&diags).contains(&DiagKind::StaleRead), "{}", render(&diags));
        let d = diags.iter().find(|d| d.kind == DiagKind::StaleRead).unwrap();
        assert_eq!((d.comp.as_str(), d.index), ("main.1", 2), "{d}");
    }

    #[test]
    fn move_of_duplicated_operand_is_an_inplace_error() {
        let mut plan = compile(CHAIN);
        let e = plan.entry;
        // make the negate read d.3 twice with a move flag on the first
        // read: stealing a register the same step reads again would
        // hand the second read a hole
        plan.comps[e].instrs[3].operands = vec![2, 2];
        plan.comps[e].take[3] = vec![true, false];
        let diags = verify(&plan);
        assert!(kinds(&diags).contains(&DiagKind::InPlace), "{}", render(&diags));
    }

    #[test]
    fn move_with_later_reader_is_an_inplace_error() {
        let text = "HloModule t\n\nENTRY main.1 {\n  x.1 = f32[3]{0} parameter(0)\n  \
            a.2 = f32[3]{0} negate(x.1)\n  \
            ROOT b.3 = f32[3]{0} add(a.2, x.1)\n}\n";
        let mut plan = compile(text);
        let e = plan.entry;
        // x.1's last use is step 2; claiming the negate (step 1) may
        // steal it would let an in-place kernel clobber a live buffer
        plan.comps[e].take[1] = vec![true];
        let diags = verify(&plan);
        let d = diags.iter().find(|d| d.kind == DiagKind::InPlace).expect("must reject");
        assert_eq!((d.comp.as_str(), d.instr.as_str(), d.index), ("main.1", "a.2", 1), "{d}");
    }

    #[test]
    fn dtype_mismatch_is_a_type_error() {
        let mut plan = compile(CHAIN);
        let e = plan.entry;
        // declare the negate result as s32: disagrees with its operand
        plan.comps[e].instrs[3].shape =
            Shape::Array { ty: ElemType::S32, dims: vec![3, 2] };
        let diags = verify(&plan);
        let type_diags: Vec<_> =
            diags.iter().filter(|d| d.kind == DiagKind::Type).collect();
        assert!(!type_diags.is_empty(), "{}", render(&diags));
        // at least one addresses the corrupted instruction
        assert!(type_diags.iter().any(|d| d.index == 3 && d.instr == "n.4"));
    }

    #[test]
    fn wrong_result_dims_are_a_type_error() {
        let mut plan = compile(CHAIN);
        let e = plan.entry;
        // dot output dims must be [3, 2]
        plan.comps[e].instrs[2].shape =
            Shape::Array { ty: ElemType::F32, dims: vec![2, 3] };
        let diags = verify(&plan);
        assert!(
            diags.iter().any(|d| d.kind == DiagKind::Type && d.index == 2),
            "{}",
            render(&diags)
        );
    }

    #[test]
    fn corrupted_counted_spec_is_a_fusion_error() {
        let mut plan = compile(COUNTED);
        let e = plan.entry;
        let wi = plan.comps[e]
            .instrs
            .iter()
            .position(|i| matches!(i.op, Op::While { .. }))
            .unwrap();
        match &mut plan.comps[e].fused[wi] {
            Fused::Counted(spec) => spec.bound += 1,
            other => panic!("while did not fuse: {other:?}"),
        }
        let diags = verify(&plan);
        let d = diags.iter().find(|d| d.kind == DiagKind::Fusion).expect("must reject");
        assert_eq!(d.index, wi, "{d}");
    }

    #[test]
    fn near_miss_loop_forced_through_fusion_is_rejected() {
        // take the spec from the matching loop...
        let good = compile(COUNTED);
        let e = good.entry;
        let wi = good.comps[e]
            .instrs
            .iter()
            .position(|i| matches!(i.op, Op::While { .. }))
            .unwrap();
        let spec = match &good.comps[e].fused[wi] {
            Fused::Counted(spec) => spec.clone(),
            other => panic!("while did not fuse: {other:?}"),
        };
        // ...and force it onto the non-unit-step near miss, which the
        // planner correctly left generic
        let step2 = COUNTED.replace("one.4 = s32[] constant(1)", "one.4 = s32[] constant(2)");
        let mut bad = compile(&step2);
        assert!(matches!(bad.comps[bad.entry].fused[wi], Fused::None));
        let be = bad.entry;
        bad.comps[be].fused[wi] = Fused::Counted(spec);
        let diags = verify(&bad);
        let d = diags.iter().find(|d| d.kind == DiagKind::Fusion).expect("must reject");
        assert!(d.message.contains("counter increment"), "{d}");
    }

    #[test]
    fn forged_threefry_annotation_is_rejected() {
        let text = "HloModule t\n\nnotfry.1 {\n  a.1 = f32[] parameter(0)\n  \
            b.2 = f32[] parameter(1)\n  ROOT add.3 = f32[] add(a.1, b.2)\n}\n\n\
            ENTRY main.1 {\n  x.1 = f32[] parameter(0)\n  y.2 = f32[] parameter(1)\n  \
            ROOT c.3 = f32[] call(x.1, y.2), to_apply=notfry.1\n}\n";
        let mut plan = compile(text);
        let e = plan.entry;
        plan.comps[e].fused[2] = Fused::Threefry;
        let diags = verify(&plan);
        assert!(
            diags.iter().any(|d| d.kind == DiagKind::Fusion && d.index == 2),
            "{}",
            render(&diags)
        );
    }

    #[test]
    fn unregistered_shard_kernel_is_rejected() {
        let plan = compile(CHAIN);
        // the full registry accepts the plan...
        assert!(verify(&plan).is_empty());
        // ...an empty registry must reject its dot/unary/fused-reduce
        let diags = verify_with_registry(&plan, &[]);
        let shard: Vec<_> =
            diags.iter().filter(|d| d.kind == DiagKind::ShardSafety).collect();
        assert!(shard.len() >= 3, "{}", render(&diags));
        assert!(shard.iter().any(|d| d.message.contains("dot[packed]")));
    }

    #[test]
    fn registry_covers_every_dispatch_site() {
        // every key sharding_kernel can produce must be declared
        for text in [CHAIN, CONV, ECHAIN] {
            let m = parse_module(text).unwrap();
            let plan = Plan::compile_unverified(&m, PlanOptions::default());
            for comp in &plan.comps {
                for (si, ins) in comp.instrs.iter().enumerate() {
                    if let Some(k) = sharding_kernel(ins, &comp.fused[si]) {
                        assert!(
                            SHARD_REGISTRY.iter().any(|e| e.name == k),
                            "kernel {k} missing from SHARD_REGISTRY"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn conv_wrong_spatial_dims_are_a_type_error() {
        let mut plan = compile(CONV);
        let e = plan.entry;
        // SAME-padded 3x3 conv over 6x6 must stay 6x6; claim 5x5
        plan.comps[e].instrs[2].shape =
            Shape::Array { ty: ElemType::F32, dims: vec![1, 5, 5, 4] };
        let diags = verify(&plan);
        let d = diags
            .iter()
            .find(|d| d.kind == DiagKind::Type && d.index == 2)
            .expect("must reject");
        assert_eq!(d.instr, "c.3", "{d}");
        assert!(d.message.contains("convolution produces f32[1, 6, 6, 4]"), "{d}");
    }

    #[test]
    fn integer_operand_into_conv_is_a_type_error() {
        let mut plan = compile(CONV);
        let e = plan.entry;
        // feed the conv an s32 image (also trips entry_params; the
        // conv-addressed dtype diagnostic must still appear)
        plan.comps[e].instrs[0].shape =
            Shape::Array { ty: ElemType::S32, dims: vec![1, 6, 6, 2] };
        let diags = verify(&plan);
        assert!(
            diags.iter().any(|d| d.kind == DiagKind::Type
                && d.index == 2
                && d.message.contains("f32-only")),
            "{}",
            render(&diags)
        );
    }

    #[test]
    fn bad_reduce_window_region_arity_is_a_type_error() {
        // grow the pool region to three parameters: the planner leaves
        // it generic, the type pass must still reject the region shape
        let text = CONV.replace(
            "b.2 = f32[] parameter(1)\n  ROOT",
            "b.2 = f32[] parameter(1)\n  c.9 = f32[] parameter(2)\n  ROOT",
        );
        let plan = compile(&text);
        assert!(matches!(plan.comps[plan.entry].fused[4], Fused::None));
        let diags = verify(&plan);
        let d = diags
            .iter()
            .find(|d| d.kind == DiagKind::Type && d.index == 4)
            .expect("must reject");
        assert_eq!(d.instr, "p.5", "{d}");
        assert!(d.message.contains("reduce-window region"), "{d}");
    }

    #[test]
    fn forged_reduce_window_fusion_is_rejected() {
        let mut plan = compile(CONV);
        let e = plan.entry;
        // claim the max pool folds with add: the re-proof must notice
        plan.comps[e].fused[4] = Fused::Bin { op: BinaryOp::Add, acc_first: true };
        let diags = verify(&plan);
        let d = diags
            .iter()
            .find(|d| d.kind == DiagKind::Fusion && d.index == 4)
            .expect("must reject");
        assert!(d.message.contains("Max"), "{d}");
    }

    #[test]
    fn unregistered_conv_shard_kernels_are_rejected() {
        let plan = compile(CONV);
        assert!(verify(&plan).is_empty());
        let diags = verify_with_registry(&plan, &[]);
        let shard: Vec<_> =
            diags.iter().filter(|d| d.kind == DiagKind::ShardSafety).collect();
        assert!(shard.iter().any(|d| d.message.contains("conv[direct]")), "{}", render(&diags));
        assert!(
            shard.iter().any(|d| d.message.contains("reduce-window[fused]")),
            "{}",
            render(&diags)
        );
    }

    #[test]
    fn census_counts_the_conv_pipeline() {
        let c = census(&compile(CONV));
        assert_eq!(c.op_counts.get("conv[direct]"), Some(&1));
        assert_eq!(c.op_counts.get("reduce-window[fused]"), Some(&1));
        assert_eq!(c.fusion.fused_windows, 1);
        assert_eq!(c.shard_kernels.get("conv[direct]"), Some(&1));
        assert_eq!(c.shard_kernels.get("reduce-window[fused]"), Some(&1));
        let s = c.to_string();
        assert!(s.contains("fused windows") && s.contains("conv[direct]"), "{s}");
    }

    #[test]
    fn census_counts_the_chain() {
        let c = census(&compile(CHAIN));
        assert_eq!(c.comps, 2);
        assert_eq!(c.op_counts.get("dot[packed]"), Some(&1));
        assert_eq!(c.op_counts.get("reduce[fused]"), Some(&1));
        assert_eq!(c.fusion.fused_reduces, 1);
        assert!(c.move_slots > 0 && c.move_slots <= c.operand_slots);
        assert_eq!(c.shard_kernels.get("dot[packed]"), Some(&1));
        // census renders without panicking and mentions the kernels
        let s = c.to_string();
        assert!(s.contains("dot[packed]") && s.contains("fused reduces"), "{s}");
    }

    // ------------------------------------------- chain superinstruction ---

    #[test]
    fn census_counts_the_elementwise_chain() {
        let (plan, _) = chain_plan();
        assert!(verify(&plan).is_empty());
        let c = census(&plan);
        assert_eq!(c.fusion.fused_chains, 1);
        assert_eq!(c.fusion.chain_steps, 4, "three elided steps plus the root");
        assert_eq!(c.op_counts.get("chain[elementwise]"), Some(&1));
        assert_eq!(c.op_counts.get("chain[interior]"), Some(&3));
        assert_eq!(c.shard_kernels.get("chain[elementwise]"), Some(&1));
        // an elided interior never dispatches a kernel
        assert!(!c.shard_kernels.contains_key("chain[interior]"));
        let s = c.to_string();
        assert!(s.contains("1 chains (4 steps)"), "{s}");
    }

    #[test]
    fn unmarked_claimed_chain_step_is_a_fusion_error() {
        let (mut plan, ri) = chain_plan();
        let e = plan.entry;
        // strip the folded broadcast's interior marker: the claim list
        // and the markers no longer agree
        plan.comps[e].fused[2] = Fused::None;
        let diags = verify(&plan);
        let d = diags.iter().find(|d| d.kind == DiagKind::Fusion).expect("must reject");
        assert_eq!(d.index, ri, "{d}");
        assert!(d.message.contains("not marked"), "{d}");
    }

    #[test]
    fn orphan_chain_interior_marker_is_a_fusion_error() {
        let (mut plan, ri) = chain_plan();
        let e = plan.entry;
        // e.4 is a materialized multi-use input of the chain; forging
        // an interior marker on it must be rejected from both sides —
        // the marker names a chain that does not claim it, and the
        // chain sees an unclaimed marker
        plan.comps[e].fused[3] = Fused::ChainInterior { root: ri };
        let diags = verify(&plan);
        assert!(
            diags.iter().any(|d| d.kind == DiagKind::Fusion && d.index == 3),
            "{}",
            render(&diags)
        );
        assert!(
            diags.iter().any(|d| d.kind == DiagKind::Fusion && d.index == ri),
            "{}",
            render(&diags)
        );
    }

    #[test]
    fn forged_chain_take_flag_is_a_fusion_error() {
        let (mut plan, ri) = chain_plan();
        let e = plan.entry;
        match &mut plan.comps[e].fused[ri] {
            Fused::Chain(spec) => {
                // all three inputs die at the root in this fixture
                assert_eq!(spec.take, vec![true, true, true]);
                spec.take[1] = false;
            }
            other => panic!("not a chain: {other:?}"),
        }
        let diags = verify(&plan);
        let d = diags.iter().find(|d| d.kind == DiagKind::Fusion).expect("must reject");
        assert_eq!(d.index, ri, "{d}");
        assert!(d.message.contains("disagrees with re-derivation"), "{d}");
    }

    #[test]
    fn forged_chain_inplace_slot_is_a_fusion_error() {
        let (mut plan, ri) = chain_plan();
        let e = plan.entry;
        match &mut plan.comps[e].fused[ri] {
            Fused::Chain(spec) => {
                assert_eq!(spec.inplace, Some(0));
                // slot 2 (x.1) is also consumable and shape-compatible,
                // but the canonical choice is the *first* such slot —
                // accepting any sound-looking slot would let planner
                // and verifier drift apart silently
                spec.inplace = Some(2);
            }
            other => panic!("not a chain: {other:?}"),
        }
        let diags = verify(&plan);
        let d = diags.iter().find(|d| d.kind == DiagKind::Fusion).expect("must reject");
        assert_eq!(d.index, ri, "{d}");
    }

    #[test]
    fn corrupted_chain_tape_is_a_fusion_error() {
        let (mut plan, ri) = chain_plan();
        let e = plan.entry;
        match &mut plan.comps[e].fused[ri] {
            Fused::Chain(spec) => {
                // the multiply becomes an add: same slots, wrong op
                spec.tape[0] =
                    TapeOp::Binary { op: BinaryOp::Add, ty: ElemType::F32, a: 0, b: 1 };
            }
            other => panic!("not a chain: {other:?}"),
        }
        let diags = verify(&plan);
        let d = diags.iter().find(|d| d.kind == DiagKind::Fusion).expect("must reject");
        assert_eq!(d.index, ri, "{d}");
        assert!(d.message.contains("disagrees with re-derivation"), "{d}");
    }

    #[test]
    fn move_flag_on_an_elided_step_is_an_inplace_error() {
        let (mut plan, _) = chain_plan();
        let e = plan.entry;
        // the broadcast never executes; its read of c.2 happens at the
        // chain root under the spec's take flags — and c.2's effective
        // last use IS the root, so only the elision check catches a
        // forged flag here
        plan.comps[e].take[2] = vec![true];
        let diags = verify(&plan);
        let d = diags.iter().find(|d| d.kind == DiagKind::InPlace).expect("must reject");
        assert_eq!(d.index, 2, "{d}");
        assert!(d.message.contains("elided"), "{d}");
    }

    #[test]
    fn move_under_a_chain_reader_is_an_inplace_error() {
        // x.1 feeds the chain only through its elided negate (step 2),
        // so its last instruction-level read is the reshape (step 3) —
        // but the chain root (step 4) physically loads it. A move flag
        // on the reshape would steal the buffer the chain is about to
        // read; only effective liveness catches this
        let mut plan = compile(SPLIT);
        let e = plan.entry;
        assert!(verify(&plan).is_empty());
        assert!(matches!(plan.comps[e].fused[4], Fused::Chain(_)));
        plan.comps[e].take[3] = vec![true];
        let diags = verify(&plan);
        let d = diags.iter().find(|d| d.kind == DiagKind::InPlace).expect("must reject");
        assert_eq!((d.instr.as_str(), d.index), ("r.4", 3), "{d}");
        assert!(d.message.contains("step 4 still reads it"), "{d}");
    }

    #[test]
    fn free_before_the_chain_root_is_a_stale_read() {
        // c.2's only instruction-level read is the elided broadcast
        // (step 2), but the splat is actually loaded when the chain
        // root runs (step 6); freeing it anywhere in between must be
        // flagged even though no instruction past step 2 names it
        let (mut plan, _) = chain_plan();
        let e = plan.entry;
        plan.comps[e].free_after[3].push(1);
        let diags = verify(&plan);
        assert!(kinds(&diags).contains(&DiagKind::StaleRead), "{}", render(&diags));
        assert!(
            diags
                .iter()
                .any(|d| d.kind == DiagKind::StaleRead && d.index == 3
                    && d.message.contains("later step still reads it")),
            "{}",
            render(&diags)
        );
    }
}
