//! Loop-fusion layer on top of [`crate::runtime::interp::plan`]
//! (DESIGN.md §4): compile-time pattern matchers that let the planned
//! executor run the interpreter's hottest loops as superinstructions.
//!
//! Two patterns are recognized:
//!
//! * **Counted `while` loops** ([`match_counted_loop`]). The loop
//!   condition is a compare of one integer state element against a
//!   constant bound (`state[idx] < bound`, `LT` only) and the body's
//!   root tuple re-binds that element to `state[idx] + 1`. The trip
//!   count is then `max(0, bound - start)`, readable from the incoming
//!   state — so the executor runs the body plan that many times with
//!   the state *unpacked once into per-element registers*: no
//!   per-iteration condition evaluation, no tuple pack/unpack steps
//!   (the body's `get-tuple-element`s of the loop parameter become
//!   direct register reads, the root tuple becomes direct register
//!   writes). Anything that doesn't match — non-constant bounds,
//!   non-unit steps, other compare directions, bodies that touch the
//!   state parameter outside `get-tuple-element` — falls back to the
//!   generic `while` path.
//! * **The threefry-2x32 round body** ([`match_threefry`]), the
//!   straight-line u32 add/xor/rotate/shift chain jax's PRNG lowers
//!   every Quant-Noise mask sample to. Matching is structural: each
//!   root tuple operand is resolved to a symbolic expression tree
//!   (`reshape` and scalar `broadcast` are transparent, a unit `slice`
//!   of a rotation parameter is a lane pick) and compared against the
//!   canonical four-round chain. Matched calls execute as the native
//!   [`crate::runtime::interp::ops::threefry2x32`] kernel — one
//!   unrolled pass over the flat u32 lane buffers.
//!
//! **Determinism argument.** The counted-loop rewrite runs the same
//! body steps on the same values in the same order; skipping the
//! condition is sound because the matched condition is pure and its
//! value is fully determined by the counter trajectory the matched
//! increment pins down. The threefry kernel is exact u32 wrapping
//! arithmetic — add/xor/or/shift have no rounding, so algebraic
//! regrouping (`(x + k) + c` vs `x + (k + c)`) is bit-exact and the
//! kernel provably equals the generic elementwise chain. Both rewrites
//! were additionally validated bit-identically against the reference
//! mirror on the committed fixture (`tools/qnsim/plan_mirror.py`).
//!
//! **Keep in sync:** [`crate::runtime::interp::verify`] re-proves both
//! patterns from the HLO with independently authored code
//! (`derive_counted`, `prove_threefry`) and rejects any plan where its
//! derivation disagrees with the annotation these matchers produced.
//! Loosening or extending a matcher here without teaching the verifier
//! the same rule turns every newly matched plan into a verification
//! failure — deliberately (DESIGN.md §8).

use std::rc::Rc;

use crate::runtime::interp::parser::{BinaryOp, CmpDir, Computation, HloModule, Instr, Op};
use crate::runtime::interp::value::{Buf, ElemType};

// ------------------------------------------------------ counted loops ---

/// Plan-time lowering of one counted `while` (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct CountedLoop {
    /// State tuple element holding the trip counter.
    pub idx: usize,
    /// Exclusive upper bound (the condition is `state[idx] < bound`).
    pub bound: i64,
    /// Body computation index.
    pub body: usize,
    /// State tuple arity.
    pub arity: usize,
    /// `(instruction index, state element)` for each
    /// `get-tuple-element` of the body's loop parameter.
    pub state_reads: Vec<(usize, usize)>,
    /// Per `state_reads` entry: move the state slot into the register
    /// instead of cloning (the slot feeds exactly that one read).
    pub take_state: Vec<bool>,
    /// Body instructions to execute per iteration, in order — the
    /// parameter, the state reads and the root tuple are elided.
    pub steps: Vec<usize>,
    /// Root tuple operand registers (`arity` of them): the next state.
    pub root_ops: Vec<usize>,
}

/// Scalar s32/u32 constant value of an instruction, if it is one.
fn scalar_int(ins: &Instr) -> Option<i64> {
    match &ins.op {
        Op::Constant(c) if c.numel() == 1 => match &*c.buf {
            Buf::S32(v) => Some(v[0] as i64),
            Buf::U32(v) => Some(v[0] as i64),
            _ => None,
        },
        _ => None,
    }
}

/// The single `Op::Parameter` instruction of a one-parameter
/// computation (None if the computation is not shaped like that).
fn single_param(c: &Computation) -> Option<usize> {
    if c.n_params != 1 {
        return None;
    }
    let mut found = None;
    for (i, ins) in c.instrs.iter().enumerate() {
        if matches!(ins.op, Op::Parameter(_)) {
            if found.is_some() {
                return None;
            }
            found = Some(i);
        }
    }
    found
}

/// Match a `while` whose `cond`/`body` computations form a counted
/// loop; returns the full execution spec or None (generic fallback).
/// Dead instructions in the condition are fine (jax's conditions unpack
/// the whole state tuple) — only the root's dependency chain matters.
pub fn match_counted_loop(m: &HloModule, cond: usize, body: usize) -> Option<CountedLoop> {
    // condition: ROOT compare(get-tuple-element(param, idx), const) LT
    let c = &m.comps[cond];
    let p = single_param(c)?;
    let root = &c.instrs[c.root];
    if !matches!(root.op, Op::Compare { dir: CmpDir::Lt }) || root.operands.len() != 2 {
        return None;
    }
    let (a, b) = (root.operands[0], root.operands[1]);
    let idx = match &c.instrs[a].op {
        Op::GetTupleElement(i) if c.instrs[a].operands == [p] => *i,
        _ => return None,
    };
    let bound = scalar_int(&c.instrs[b])?;

    // body: one param used only by get-tuple-element, ROOT tuple whose
    // element `idx` is add(get-tuple-element(param, idx), 1)
    let bc = &m.comps[body];
    let bp = single_param(bc)?;
    let broot = &bc.instrs[bc.root];
    if !matches!(broot.op, Op::Tuple) {
        return None;
    }
    let root_ops = broot.operands.clone();
    let arity = root_ops.len();
    if idx >= arity {
        return None;
    }
    let mut state_reads = Vec::new();
    for (i, ins) in bc.instrs.iter().enumerate() {
        match &ins.op {
            Op::GetTupleElement(e) if ins.operands == [bp] => {
                if *e >= arity {
                    return None;
                }
                state_reads.push((i, *e));
            }
            _ => {
                if ins.operands.contains(&bp) {
                    return None;
                }
            }
        }
    }
    let inc = &bc.instrs[root_ops[idx]];
    if !matches!(inc.op, Op::Binary(BinaryOp::Add)) || inc.operands.len() != 2 {
        return None;
    }
    let is_counter =
        |i: usize| state_reads.iter().any(|&(gi, e)| gi == i && e == idx);
    let is_one = |i: usize| scalar_int(&bc.instrs[i]) == Some(1);
    let (x, y) = (inc.operands[0], inc.operands[1]);
    if !(is_counter(x) && is_one(y) || is_counter(y) && is_one(x)) {
        return None;
    }

    let take_state: Vec<bool> = state_reads
        .iter()
        .map(|&(_, e)| state_reads.iter().filter(|&&(_, e2)| e2 == e).count() == 1)
        .collect();
    let steps: Vec<usize> = (0..bc.instrs.len())
        .filter(|&i| i != bp && i != bc.root && !state_reads.iter().any(|&(gi, _)| gi == i))
        .collect();
    Some(CountedLoop { idx, bound, body, arity, state_reads, take_state, steps, root_ops })
}

// ----------------------------------------------------------- threefry ---

/// Symbolic expression over a straight-line u32 computation. `reshape`
/// is transparent, `broadcast` of a one-element value is transparent
/// (a splat — the kernel applies scalars per lane), and a unit slice
/// of a parameter is a lane pick — so the u32[1] and u32[N] lowerings
/// of the same round body resolve to the identical tree.
#[derive(Debug, PartialEq)]
enum Ex {
    /// Parameter `k`'s (scalar-broadcast) value.
    P(usize),
    /// Scalar u32 constant.
    Cu(u32),
    /// Scalar s32 constant.
    Cs(i32),
    /// `slice(parameter k)[j:j+1]`.
    Lane(usize, usize),
    /// `convert` s32 → u32.
    U32(Rc<Ex>),
    Bin(BinaryOp, Rc<Ex>, Rc<Ex>),
}

fn resolve(c: &Computation, i: usize, memo: &mut [Option<Option<Rc<Ex>>>]) -> Option<Rc<Ex>> {
    if let Some(r) = &memo[i] {
        return r.clone();
    }
    let ins = &c.instrs[i];
    let r: Option<Rc<Ex>> = match &ins.op {
        Op::Parameter(k) => Some(Rc::new(Ex::P(*k))),
        Op::Constant(a) if a.numel() == 1 => match &*a.buf {
            Buf::U32(v) => Some(Rc::new(Ex::Cu(v[0]))),
            Buf::S32(v) => Some(Rc::new(Ex::Cs(v[0]))),
            _ => None,
        },
        Op::Reshape => resolve(c, ins.operands[0], memo),
        Op::Broadcast { .. } => {
            let o = ins.operands[0];
            if c.instrs[o].shape.numel() == 1 {
                resolve(c, o, memo)
            } else {
                None
            }
        }
        Op::Convert => {
            let o = ins.operands[0];
            let to = ins.shape.array().map(|(t, _)| t);
            let from = c.instrs[o].shape.array().map(|(t, _)| t);
            match (from, to) {
                (Ok(ElemType::S32), Ok(ElemType::U32)) => {
                    resolve(c, o, memo).map(|e| Rc::new(Ex::U32(e)))
                }
                _ => None,
            }
        }
        Op::Slice { spec } => match (&c.instrs[ins.operands[0]].op, &spec[..]) {
            (Op::Parameter(k), &[(s, l, 1)]) if l == s + 1 => {
                Some(Rc::new(Ex::Lane(*k, s)))
            }
            _ => None,
        },
        Op::Binary(
            b @ (BinaryOp::Add
            | BinaryOp::Xor
            | BinaryOp::Or
            | BinaryOp::Sub
            | BinaryOp::Shl
            | BinaryOp::ShrLogical),
        ) if ins.operands.len() == 2 => {
            match (resolve(c, ins.operands[0], memo), resolve(c, ins.operands[1], memo)) {
                (Some(x), Some(y)) => Some(Rc::new(Ex::Bin(*b, x, y))),
                _ => None,
            }
        }
        _ => None,
    };
    memo[i] = Some(r.clone());
    r
}

/// The canonical four-round threefry-2x32 body as jax lowers it:
/// the eight root tuple operands `(i+1, x0', x1', k1, k2, k0, rot_b,
/// rot_a)` in terms of the eight parameters
/// `(i, x0, x1, k0, k1, k2, rot_a, rot_b)`.
fn expected_round() -> [Rc<Ex>; 8] {
    let p = |k| Rc::new(Ex::P(k));
    let lane = |j| Rc::new(Ex::Lane(6, j));
    let bin = |b, x: &Rc<Ex>, y: &Rc<Ex>| Rc::new(Ex::Bin(b, x.clone(), y.clone()));
    let rot = |x: &Rc<Ex>, j: usize| {
        bin(
            BinaryOp::Or,
            &bin(BinaryOp::Shl, x, &lane(j)),
            &bin(
                BinaryOp::ShrLogical,
                x,
                &bin(BinaryOp::Sub, &Rc::new(Ex::Cu(32)), &lane(j)),
            ),
        )
    };
    let mut x0 = bin(BinaryOp::Add, &p(1), &p(2));
    let mut x1 = bin(BinaryOp::Xor, &x0, &rot(&p(2), 0));
    for j in 1..4 {
        let nx0 = bin(BinaryOp::Add, &x0, &x1);
        x1 = bin(BinaryOp::Xor, &nx0, &rot(&x1, j));
        x0 = nx0;
    }
    let out_i = bin(BinaryOp::Add, &p(0), &Rc::new(Ex::Cs(1)));
    let out_x0 = bin(BinaryOp::Add, &x0, &p(3));
    let out_x1 = bin(
        BinaryOp::Add,
        &bin(BinaryOp::Add, &x1, &p(4)),
        &Rc::new(Ex::U32(out_i.clone())),
    );
    [out_i, out_x0, out_x1, p(4), p(5), p(3), p(7), p(6)]
}

/// Does `c` compute exactly one jax threefry-2x32 round group (four
/// rounds + key injection + key/rotation rotation)? Matched call sites
/// run [`crate::runtime::interp::ops::threefry2x32`] natively.
pub fn match_threefry(c: &Computation) -> bool {
    if c.n_params != 8 {
        return false;
    }
    // one Parameter instruction per number, with the canonical shapes:
    // (s32[], u32[N], u32[N], u32[], u32[], u32[], u32[4], u32[4])
    let mut pshape: [Option<(ElemType, &[usize])>; 8] = [None; 8];
    for ins in &c.instrs {
        if let Op::Parameter(k) = ins.op {
            let Ok(sh) = ins.shape.array() else { return false };
            if k >= 8 || pshape[k].replace(sh).is_some() {
                return false;
            }
        }
    }
    let Some(shapes) = pshape.into_iter().collect::<Option<Vec<_>>>() else {
        return false;
    };
    let scalar = |k: usize, ty| shapes[k] == (ty, &[][..]);
    if !scalar(0, ElemType::S32) || !scalar(3, ElemType::U32) {
        return false;
    }
    if !scalar(4, ElemType::U32) || !scalar(5, ElemType::U32) {
        return false;
    }
    let lanes_ok = shapes[1].0 == ElemType::U32 && shapes[1] == shapes[2];
    let rots_ok = shapes[6] == (ElemType::U32, &[4][..]) && shapes[6] == shapes[7];
    if !lanes_ok || !rots_ok {
        return false;
    }
    let root = &c.instrs[c.root];
    if !matches!(root.op, Op::Tuple) || root.operands.len() != 8 {
        return false;
    }
    // output shapes must be the canonical state shapes: resolve() sees
    // through reshape/broadcast, but the executor rebuilds the result
    // tuple from the input shapes, so a shape-changing wrapper on a
    // root operand must fall back to the generic call
    let out_shapes = [shapes[0], shapes[1], shapes[2], shapes[4], shapes[5], shapes[3],
        shapes[7], shapes[6]];
    for (&o, want) in root.operands.iter().zip(&out_shapes) {
        match c.instrs[o].shape.array() {
            Ok(sh) if sh == *want => {}
            _ => return false,
        }
    }
    let mut memo = vec![None; c.instrs.len()];
    let want = expected_round();
    root.operands
        .iter()
        .zip(&want)
        .all(|(&o, w)| resolve(c, o, &mut memo).is_some_and(|e| e == *w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::interp::parser::parse_module;

    /// A minimal counted loop: state (i, acc), i < 4, i += 1.
    const COUNTED: &str = "HloModule t\n\ncond.1 {\n  s.1 = (s32[], f32[2]) parameter(0)\n  \
        i.2 = s32[] get-tuple-element(s.1), index=0\n  n.3 = s32[] constant(4)\n  \
        ROOT lt.4 = pred[] compare(i.2, n.3), direction=LT\n}\n\nbody.1 {\n  \
        s.1 = (s32[], f32[2]) parameter(0)\n  i.2 = s32[] get-tuple-element(s.1), index=0\n  \
        v.3 = f32[2]{0} get-tuple-element(s.1), index=1\n  one.4 = s32[] constant(1)\n  \
        c.5 = f32[2]{0} constant({0.5, 0.25})\n  i2.6 = s32[] add(i.2, one.4)\n  \
        v2.7 = f32[2]{0} add(v.3, c.5)\n  \
        ROOT t.8 = (s32[], f32[2]) tuple(i2.6, v2.7)\n}\n\nENTRY main.1 {\n  \
        z.1 = s32[] constant(0)\n  v0.2 = f32[2]{0} parameter(0)\n  \
        st.3 = (s32[], f32[2]) tuple(z.1, v0.2)\n  \
        ROOT w.4 = (s32[], f32[2]) while(st.3), condition=cond.1, body=body.1\n}\n";

    #[test]
    fn counted_loop_matches_and_plans_register_map() {
        let m = parse_module(COUNTED).unwrap();
        let spec = match_counted_loop(&m, 0, 1).expect("counted loop must match");
        assert_eq!((spec.idx, spec.bound, spec.arity), (0, 4, 2));
        // body: param(0), gte i(1), gte v(2), const(3), const(4),
        // add(5), add(6), tuple(7)
        assert_eq!(spec.state_reads, vec![(1, 0), (2, 1)]);
        assert_eq!(spec.take_state, vec![true, true]);
        assert_eq!(spec.steps, vec![3, 4, 5, 6]);
        assert_eq!(spec.root_ops, vec![5, 6]);
    }

    #[test]
    fn counted_loop_rejects_near_misses() {
        // non-unit step
        let step2 = COUNTED.replace("one.4 = s32[] constant(1)", "one.4 = s32[] constant(2)");
        let m = parse_module(&step2).unwrap();
        assert!(match_counted_loop(&m, 0, 1).is_none(), "step 2 must fall back");
        // wrong compare direction
        let ge = COUNTED.replace("direction=LT", "direction=GE");
        let m = parse_module(&ge).unwrap();
        assert!(match_counted_loop(&m, 0, 1).is_none(), "GE must fall back");
        // non-constant bound (bound read from the state itself)
        let varb = COUNTED.replace(
            "n.3 = s32[] constant(4)",
            "n.3 = s32[] get-tuple-element(s.1), index=0",
        );
        let m = parse_module(&varb).unwrap();
        assert!(match_counted_loop(&m, 0, 1).is_none(), "dynamic bound must fall back");
        // counter rebound to something that is not add(counter, 1)
        let mul = COUNTED
            .replace("i2.6 = s32[] add(i.2, one.4)", "i2.6 = s32[] multiply(i.2, one.4)");
        let m = parse_module(&mul).unwrap();
        assert!(match_counted_loop(&m, 0, 1).is_none(), "multiply must fall back");
    }

    #[test]
    fn threefry_rejects_non_round_bodies() {
        // the counted-loop fixture bodies are nothing like a round body
        let m = parse_module(COUNTED).unwrap();
        assert!(!match_threefry(&m.comps[0]));
        assert!(!match_threefry(&m.comps[1]));
        assert!(!match_threefry(&m.comps[2]));
    }

    #[test]
    fn expected_round_tree_is_stable() {
        // the canonical tree must stay in lockstep with the kernel: a
        // quick structural sanity check of its outer spine
        let want = expected_round();
        assert_eq!(*want[3], Ex::P(4));
        assert_eq!(*want[5], Ex::P(3));
        match &*want[0] {
            Ex::Bin(BinaryOp::Add, a, b) => {
                assert_eq!(**a, Ex::P(0));
                assert_eq!(**b, Ex::Cs(1));
            }
            other => panic!("{other:?}"),
        }
        match &*want[2] {
            Ex::Bin(BinaryOp::Add, _, conv) => {
                assert!(matches!(&**conv, Ex::U32(_)));
            }
            other => panic!("{other:?}"),
        }
    }
}
