//! Loop-fusion layer on top of [`crate::runtime::interp::plan`]
//! (DESIGN.md §4): compile-time pattern matchers that let the planned
//! executor run the interpreter's hottest loops as superinstructions.
//!
//! Three patterns are recognized:
//!
//! * **Counted `while` loops** ([`match_counted_loop`]). The loop
//!   condition is a compare of one integer state element against a
//!   constant bound (`state[idx] < bound`, `LT` only) and the body's
//!   root tuple re-binds that element to `state[idx] + 1`. The trip
//!   count is then `max(0, bound - start)`, readable from the incoming
//!   state — so the executor runs the body plan that many times with
//!   the state *unpacked once into per-element registers*: no
//!   per-iteration condition evaluation, no tuple pack/unpack steps
//!   (the body's `get-tuple-element`s of the loop parameter become
//!   direct register reads, the root tuple becomes direct register
//!   writes). Anything that doesn't match — non-constant bounds,
//!   non-unit steps, other compare directions, bodies that touch the
//!   state parameter outside `get-tuple-element` — falls back to the
//!   generic `while` path.
//! * **The threefry-2x32 round body** ([`match_threefry`]), the
//!   straight-line u32 add/xor/rotate/shift chain jax's PRNG lowers
//!   every Quant-Noise mask sample to. Matching is structural: each
//!   root tuple operand is resolved to a symbolic expression tree
//!   (`reshape` and scalar `broadcast` are transparent, a unit `slice`
//!   of a rotation parameter is a lane pick) and compared against the
//!   canonical four-round chain. Matched calls execute as the native
//!   [`crate::runtime::interp::ops::threefry2x32`] kernel — one
//!   unrolled pass over the flat u32 lane buffers.
//! * **Elementwise chains** ([`match_chains`]). Runs of single-use
//!   same-shape elementwise steps (`unary`/`binary`/`select`/
//!   `compare`/`convert`, plus single-use `broadcast`s of one-element
//!   values, which become splat inputs) collapse into one
//!   [`ChainSpec`] superinstruction at the last step of the run: a
//!   compiled per-element op tape the executor evaluates in a single
//!   pass over the output buffer — no intermediate buffers, one
//!   dispatch instead of one per step, in place on a dying operand
//!   when the planner's liveness pass finds one. Multi-use
//!   intermediates stay external inputs (diamonds are fine — the value
//!   is loaded once per element per slot), `bitcast-convert` and
//!   anything shape-changing falls back to standalone steps.
//!
//! **Determinism argument.** The counted-loop rewrite runs the same
//! body steps on the same values in the same order; skipping the
//! condition is sound because the matched condition is pure and its
//! value is fully determined by the counter trajectory the matched
//! increment pins down. The threefry kernel is exact u32 wrapping
//! arithmetic — add/xor/or/shift have no rounding, so algebraic
//! regrouping (`(x + k) + c` vs `x + (k + c)`) is bit-exact and the
//! kernel provably equals the generic elementwise chain. The chain
//! tape applies the *same scalar helpers* as the standalone kernels to
//! the same operands in the same element order (the tape is evaluated
//! per output element, and elementwise ops never read across
//! elements), so elision of the intermediate buffers cannot change a
//! single bit. All rewrites were additionally validated bit-identically
//! against the reference mirror on the committed fixtures
//! (`tools/qnsim/plan_mirror.py`).
//!
//! **Keep in sync:** [`crate::runtime::interp::verify`] re-proves all
//! three patterns from the HLO with independently authored code
//! (`derive_counted`, `prove_threefry`, `derive_chains`) and rejects
//! any plan where its derivation disagrees with the annotation these
//! matchers produced.
//! Loosening or extending a matcher here without teaching the verifier
//! the same rule turns every newly matched plan into a verification
//! failure — deliberately (DESIGN.md §8).

use std::rc::Rc;

use crate::runtime::interp::ops;
use crate::runtime::interp::parser::{BinaryOp, CmpDir, Computation, HloModule, Instr, Op};
use crate::runtime::interp::value::{Buf, ElemType};

// ------------------------------------------------------ counted loops ---

/// Plan-time lowering of one counted `while` (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct CountedLoop {
    /// State tuple element holding the trip counter.
    pub idx: usize,
    /// Exclusive upper bound (the condition is `state[idx] < bound`).
    pub bound: i64,
    /// Body computation index.
    pub body: usize,
    /// State tuple arity.
    pub arity: usize,
    /// `(instruction index, state element)` for each
    /// `get-tuple-element` of the body's loop parameter.
    pub state_reads: Vec<(usize, usize)>,
    /// Per `state_reads` entry: move the state slot into the register
    /// instead of cloning (the slot feeds exactly that one read).
    pub take_state: Vec<bool>,
    /// Body instructions to execute per iteration, in order — the
    /// parameter, the state reads and the root tuple are elided.
    pub steps: Vec<usize>,
    /// Root tuple operand registers (`arity` of them): the next state.
    pub root_ops: Vec<usize>,
}

/// Scalar s32/u32 constant value of an instruction, if it is one.
fn scalar_int(ins: &Instr) -> Option<i64> {
    match &ins.op {
        Op::Constant(c) if c.numel() == 1 => match &*c.buf {
            Buf::S32(v) => Some(v[0] as i64),
            Buf::U32(v) => Some(v[0] as i64),
            _ => None,
        },
        _ => None,
    }
}

/// The single `Op::Parameter` instruction of a one-parameter
/// computation (None if the computation is not shaped like that).
fn single_param(c: &Computation) -> Option<usize> {
    if c.n_params != 1 {
        return None;
    }
    let mut found = None;
    for (i, ins) in c.instrs.iter().enumerate() {
        if matches!(ins.op, Op::Parameter(_)) {
            if found.is_some() {
                return None;
            }
            found = Some(i);
        }
    }
    found
}

/// Match a `while` whose `cond`/`body` computations form a counted
/// loop; returns the full execution spec or None (generic fallback).
/// Dead instructions in the condition are fine (jax's conditions unpack
/// the whole state tuple) — only the root's dependency chain matters.
pub fn match_counted_loop(m: &HloModule, cond: usize, body: usize) -> Option<CountedLoop> {
    // condition: ROOT compare(get-tuple-element(param, idx), const) LT
    let c = &m.comps[cond];
    let p = single_param(c)?;
    let root = &c.instrs[c.root];
    if !matches!(root.op, Op::Compare { dir: CmpDir::Lt }) || root.operands.len() != 2 {
        return None;
    }
    let (a, b) = (root.operands[0], root.operands[1]);
    let idx = match &c.instrs[a].op {
        Op::GetTupleElement(i) if c.instrs[a].operands == [p] => *i,
        _ => return None,
    };
    let bound = scalar_int(&c.instrs[b])?;

    // body: one param used only by get-tuple-element, ROOT tuple whose
    // element `idx` is add(get-tuple-element(param, idx), 1)
    let bc = &m.comps[body];
    let bp = single_param(bc)?;
    let broot = &bc.instrs[bc.root];
    if !matches!(broot.op, Op::Tuple) {
        return None;
    }
    let root_ops = broot.operands.clone();
    let arity = root_ops.len();
    if idx >= arity {
        return None;
    }
    let mut state_reads = Vec::new();
    for (i, ins) in bc.instrs.iter().enumerate() {
        match &ins.op {
            Op::GetTupleElement(e) if ins.operands == [bp] => {
                if *e >= arity {
                    return None;
                }
                state_reads.push((i, *e));
            }
            _ => {
                if ins.operands.contains(&bp) {
                    return None;
                }
            }
        }
    }
    let inc = &bc.instrs[root_ops[idx]];
    if !matches!(inc.op, Op::Binary(BinaryOp::Add)) || inc.operands.len() != 2 {
        return None;
    }
    let is_counter =
        |i: usize| state_reads.iter().any(|&(gi, e)| gi == i && e == idx);
    let is_one = |i: usize| scalar_int(&bc.instrs[i]) == Some(1);
    let (x, y) = (inc.operands[0], inc.operands[1]);
    if !(is_counter(x) && is_one(y) || is_counter(y) && is_one(x)) {
        return None;
    }

    let take_state: Vec<bool> = state_reads
        .iter()
        .map(|&(_, e)| state_reads.iter().filter(|&&(_, e2)| e2 == e).count() == 1)
        .collect();
    let steps: Vec<usize> = (0..bc.instrs.len())
        .filter(|&i| i != bp && i != bc.root && !state_reads.iter().any(|&(gi, _)| gi == i))
        .collect();
    Some(CountedLoop { idx, bound, body, arity, state_reads, take_state, steps, root_ops })
}

// ------------------------------------------------- elementwise chains ---

/// One external input of an elementwise chain, in slot order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChainInput {
    /// Loaded per element from this register (same dims as the chain).
    Full(usize),
    /// A single-use `broadcast` of a one-element value folded into the
    /// chain: the register's lone element is splatted into the slot
    /// once per kernel invocation instead of materializing the
    /// broadcast.
    Scalar(usize),
}

impl ChainInput {
    /// The register this slot reads.
    pub fn reg(self) -> usize {
        match self {
            ChainInput::Full(r) | ChainInput::Scalar(r) => r,
        }
    }
}

/// Plan-time spec of one elementwise-chain superinstruction, attached
/// as [`crate::runtime::interp::plan::Fused::Chain`] to the chain's
/// last step (the *root*); every other member carries
/// `Fused::ChainInterior` back-pointing at the root and is never
/// executed — its register is never written.
#[derive(Debug, Clone, PartialEq)]
pub struct ChainSpec {
    /// Elided member steps (ascending): the single-use elementwise
    /// interiors plus folded broadcast-of-scalar steps. Excludes the
    /// root itself.
    pub steps: Vec<usize>,
    /// External inputs in slot order (slot `i` is `inputs[i]`).
    pub inputs: Vec<ChainInput>,
    /// Per input: the chain root is the register's last effective use
    /// and the kernel may consume it. Filled by the planner's liveness
    /// pass; the matcher produces all-false.
    pub take: Vec<bool>,
    /// Input slot whose buffer the chain overwrites in place (always a
    /// `Full` slot with `take` set whose value matches the output's
    /// type and dims); `None` allocates a fresh output.
    pub inplace: Option<usize>,
    /// Per-element op tape in program order: op `t` writes slot
    /// `inputs.len() + t`, the last op produces the root's value.
    pub tape: Vec<ops::TapeOp>,
}

/// Greedily grow maximal elementwise chains over one computation (see
/// module docs); returns `(root, spec)` pairs in ascending root order.
/// Roots are tried from the last instruction down, so every consumer
/// absorbs its single-use producers before those are considered as
/// roots themselves — chains are maximal cones, and no step is claimed
/// twice.
pub fn match_chains(c: &Computation) -> Vec<(usize, ChainSpec)> {
    let n = c.instrs.len();
    let mut uses = vec![0usize; n];
    for ins in &c.instrs {
        for &o in &ins.operands {
            uses[o] += 1;
        }
    }
    // the computation root's value escapes: count the escape as a use
    // so the root instruction is never elided into a consumer
    uses[c.root] += 1;

    let arr_dims = |i: usize| c.instrs[i].shape.array().ok().map(|(_, d)| d);
    let fusable = |i: usize| {
        matches!(
            c.instrs[i].op,
            Op::Unary(_) | Op::Binary(_) | Op::Select | Op::Compare { .. } | Op::Convert
        )
    };

    let mut claimed = vec![false; n];
    let mut out = Vec::new();
    'roots: for root in (0..n).rev() {
        if claimed[root] || !fusable(root) {
            continue;
        }
        let Some(dims) = arr_dims(root) else { continue };
        // the cone of single-use same-shape fusable producers
        let mut member = vec![false; n];
        member[root] = true;
        let mut count = 1usize;
        let mut stack = vec![root];
        while let Some(s) = stack.pop() {
            for &o in &c.instrs[s].operands {
                if !member[o]
                    && !claimed[o]
                    && fusable(o)
                    && uses[o] == 1
                    && arr_dims(o) == Some(dims)
                {
                    member[o] = true;
                    count += 1;
                    stack.push(o);
                }
            }
        }
        if count < 2 {
            continue; // a lone step gains nothing from a tape
        }
        let members: Vec<usize> = (0..=root).filter(|&i| member[i]).collect();

        // slot assignment: external inputs in first-reference order,
        // then one tape slot per member in program order
        let mut tape_slot = vec![usize::MAX; n];
        for (t, &s) in members.iter().enumerate() {
            tape_slot[s] = t;
        }
        let mut inputs: Vec<ChainInput> = Vec::new();
        let mut folded: Vec<usize> = Vec::new();
        let mut in_slot = vec![usize::MAX; n];
        for &s in &members {
            for &o in &c.instrs[s].operands {
                if tape_slot[o] != usize::MAX || in_slot[o] != usize::MAX {
                    continue; // a member, or already assigned a slot
                }
                // a single-use broadcast of a one-element value folds
                // into the chain as a splat slot
                let fold = matches!(c.instrs[o].op, Op::Broadcast { .. })
                    && uses[o] == 1
                    && !claimed[o]
                    && arr_dims(o) == Some(dims)
                    && c.instrs[o]
                        .operands
                        .first()
                        .is_some_and(|&src| c.instrs[src].shape.numel() == 1 && !member[src]);
                in_slot[o] = inputs.len();
                if fold {
                    folded.push(o);
                    inputs.push(ChainInput::Scalar(c.instrs[o].operands[0]));
                } else if arr_dims(o) == Some(dims) {
                    inputs.push(ChainInput::Full(o));
                } else {
                    // ill-shaped operand: keep the standalone kernels'
                    // error path by not fusing this cone at all
                    continue 'roots;
                }
            }
        }
        if inputs.len() + members.len() > u16::MAX as usize {
            continue;
        }

        let n_in = inputs.len();
        let sl = |o: usize| {
            if tape_slot[o] != usize::MAX {
                (n_in + tape_slot[o]) as u16
            } else {
                in_slot[o] as u16
            }
        };
        let mut tape = Vec::with_capacity(members.len());
        for &s in &members {
            let ins = &c.instrs[s];
            let Ok((oty, _)) = ins.shape.array() else { continue 'roots };
            let ity =
                |k: usize| c.instrs[ins.operands[k]].shape.array().ok().map(|(t, _)| t);
            let op = match (&ins.op, ins.operands.as_slice()) {
                (Op::Unary(u), &[a]) => {
                    Some(ops::TapeOp::Unary { op: *u, ty: oty, a: sl(a) })
                }
                (Op::Binary(bo), &[a, b]) => {
                    Some(ops::TapeOp::Binary { op: *bo, ty: oty, a: sl(a), b: sl(b) })
                }
                (Op::Compare { dir }, &[a, b]) => {
                    ity(0).map(|t| ops::TapeOp::Compare { dir: *dir, ty: t, a: sl(a), b: sl(b) })
                }
                (Op::Select, &[p, t, f]) => {
                    Some(ops::TapeOp::Select { p: sl(p), t: sl(t), f: sl(f) })
                }
                (Op::Convert, &[a]) => {
                    ity(0).map(|t| ops::TapeOp::Convert { from: t, to: oty, a: sl(a) })
                }
                _ => None,
            };
            match op {
                Some(t) => tape.push(t),
                None => continue 'roots, // unexpected arity: fall back
            }
        }

        let mut steps: Vec<usize> =
            members.iter().copied().filter(|&s| s != root).chain(folded).collect();
        steps.sort_unstable();
        for &s in &steps {
            claimed[s] = true;
        }
        claimed[root] = true;
        let take = vec![false; inputs.len()];
        out.push((root, ChainSpec { steps, inputs, take, inplace: None, tape }));
    }
    out.reverse(); // ascending root order reads better in diagnostics
    out
}

// ----------------------------------------------------------- threefry ---

/// Symbolic expression over a straight-line u32 computation. `reshape`
/// is transparent, `broadcast` of a one-element value is transparent
/// (a splat — the kernel applies scalars per lane), and a unit slice
/// of a parameter is a lane pick — so the u32[1] and u32[N] lowerings
/// of the same round body resolve to the identical tree.
#[derive(Debug, PartialEq)]
enum Ex {
    /// Parameter `k`'s (scalar-broadcast) value.
    P(usize),
    /// Scalar u32 constant.
    Cu(u32),
    /// Scalar s32 constant.
    Cs(i32),
    /// `slice(parameter k)[j:j+1]`.
    Lane(usize, usize),
    /// `convert` s32 → u32.
    U32(Rc<Ex>),
    Bin(BinaryOp, Rc<Ex>, Rc<Ex>),
}

fn resolve(c: &Computation, i: usize, memo: &mut [Option<Option<Rc<Ex>>>]) -> Option<Rc<Ex>> {
    if let Some(r) = &memo[i] {
        return r.clone();
    }
    let ins = &c.instrs[i];
    let r: Option<Rc<Ex>> = match &ins.op {
        Op::Parameter(k) => Some(Rc::new(Ex::P(*k))),
        Op::Constant(a) if a.numel() == 1 => match &*a.buf {
            Buf::U32(v) => Some(Rc::new(Ex::Cu(v[0]))),
            Buf::S32(v) => Some(Rc::new(Ex::Cs(v[0]))),
            _ => None,
        },
        Op::Reshape => resolve(c, ins.operands[0], memo),
        Op::Broadcast { .. } => {
            let o = ins.operands[0];
            if c.instrs[o].shape.numel() == 1 {
                resolve(c, o, memo)
            } else {
                None
            }
        }
        Op::Convert => {
            let o = ins.operands[0];
            let to = ins.shape.array().map(|(t, _)| t);
            let from = c.instrs[o].shape.array().map(|(t, _)| t);
            match (from, to) {
                (Ok(ElemType::S32), Ok(ElemType::U32)) => {
                    resolve(c, o, memo).map(|e| Rc::new(Ex::U32(e)))
                }
                _ => None,
            }
        }
        Op::Slice { spec } => match (&c.instrs[ins.operands[0]].op, &spec[..]) {
            (Op::Parameter(k), &[(s, l, 1)]) if l == s + 1 => {
                Some(Rc::new(Ex::Lane(*k, s)))
            }
            _ => None,
        },
        Op::Binary(
            b @ (BinaryOp::Add
            | BinaryOp::Xor
            | BinaryOp::Or
            | BinaryOp::Sub
            | BinaryOp::Shl
            | BinaryOp::ShrLogical),
        ) if ins.operands.len() == 2 => {
            match (resolve(c, ins.operands[0], memo), resolve(c, ins.operands[1], memo)) {
                (Some(x), Some(y)) => Some(Rc::new(Ex::Bin(*b, x, y))),
                _ => None,
            }
        }
        _ => None,
    };
    memo[i] = Some(r.clone());
    r
}

/// The canonical four-round threefry-2x32 body as jax lowers it:
/// the eight root tuple operands `(i+1, x0', x1', k1, k2, k0, rot_b,
/// rot_a)` in terms of the eight parameters
/// `(i, x0, x1, k0, k1, k2, rot_a, rot_b)`.
fn expected_round() -> [Rc<Ex>; 8] {
    let p = |k| Rc::new(Ex::P(k));
    let lane = |j| Rc::new(Ex::Lane(6, j));
    let bin = |b, x: &Rc<Ex>, y: &Rc<Ex>| Rc::new(Ex::Bin(b, x.clone(), y.clone()));
    let rot = |x: &Rc<Ex>, j: usize| {
        bin(
            BinaryOp::Or,
            &bin(BinaryOp::Shl, x, &lane(j)),
            &bin(
                BinaryOp::ShrLogical,
                x,
                &bin(BinaryOp::Sub, &Rc::new(Ex::Cu(32)), &lane(j)),
            ),
        )
    };
    let mut x0 = bin(BinaryOp::Add, &p(1), &p(2));
    let mut x1 = bin(BinaryOp::Xor, &x0, &rot(&p(2), 0));
    for j in 1..4 {
        let nx0 = bin(BinaryOp::Add, &x0, &x1);
        x1 = bin(BinaryOp::Xor, &nx0, &rot(&x1, j));
        x0 = nx0;
    }
    let out_i = bin(BinaryOp::Add, &p(0), &Rc::new(Ex::Cs(1)));
    let out_x0 = bin(BinaryOp::Add, &x0, &p(3));
    let out_x1 = bin(
        BinaryOp::Add,
        &bin(BinaryOp::Add, &x1, &p(4)),
        &Rc::new(Ex::U32(out_i.clone())),
    );
    [out_i, out_x0, out_x1, p(4), p(5), p(3), p(7), p(6)]
}

/// Does `c` compute exactly one jax threefry-2x32 round group (four
/// rounds + key injection + key/rotation rotation)? Matched call sites
/// run [`crate::runtime::interp::ops::threefry2x32`] natively.
pub fn match_threefry(c: &Computation) -> bool {
    if c.n_params != 8 {
        return false;
    }
    // one Parameter instruction per number, with the canonical shapes:
    // (s32[], u32[N], u32[N], u32[], u32[], u32[], u32[4], u32[4])
    let mut pshape: [Option<(ElemType, &[usize])>; 8] = [None; 8];
    for ins in &c.instrs {
        if let Op::Parameter(k) = ins.op {
            let Ok(sh) = ins.shape.array() else { return false };
            if k >= 8 || pshape[k].replace(sh).is_some() {
                return false;
            }
        }
    }
    let Some(shapes) = pshape.into_iter().collect::<Option<Vec<_>>>() else {
        return false;
    };
    let scalar = |k: usize, ty| shapes[k] == (ty, &[][..]);
    if !scalar(0, ElemType::S32) || !scalar(3, ElemType::U32) {
        return false;
    }
    if !scalar(4, ElemType::U32) || !scalar(5, ElemType::U32) {
        return false;
    }
    let lanes_ok = shapes[1].0 == ElemType::U32 && shapes[1] == shapes[2];
    let rots_ok = shapes[6] == (ElemType::U32, &[4][..]) && shapes[6] == shapes[7];
    if !lanes_ok || !rots_ok {
        return false;
    }
    let root = &c.instrs[c.root];
    if !matches!(root.op, Op::Tuple) || root.operands.len() != 8 {
        return false;
    }
    // output shapes must be the canonical state shapes: resolve() sees
    // through reshape/broadcast, but the executor rebuilds the result
    // tuple from the input shapes, so a shape-changing wrapper on a
    // root operand must fall back to the generic call
    let out_shapes = [shapes[0], shapes[1], shapes[2], shapes[4], shapes[5], shapes[3],
        shapes[7], shapes[6]];
    for (&o, want) in root.operands.iter().zip(&out_shapes) {
        match c.instrs[o].shape.array() {
            Ok(sh) if sh == *want => {}
            _ => return false,
        }
    }
    let mut memo = vec![None; c.instrs.len()];
    let want = expected_round();
    root.operands
        .iter()
        .zip(&want)
        .all(|(&o, w)| resolve(c, o, &mut memo).is_some_and(|e| e == *w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::interp::parser::parse_module;

    /// A minimal counted loop: state (i, acc), i < 4, i += 1.
    const COUNTED: &str = "HloModule t\n\ncond.1 {\n  s.1 = (s32[], f32[2]) parameter(0)\n  \
        i.2 = s32[] get-tuple-element(s.1), index=0\n  n.3 = s32[] constant(4)\n  \
        ROOT lt.4 = pred[] compare(i.2, n.3), direction=LT\n}\n\nbody.1 {\n  \
        s.1 = (s32[], f32[2]) parameter(0)\n  i.2 = s32[] get-tuple-element(s.1), index=0\n  \
        v.3 = f32[2]{0} get-tuple-element(s.1), index=1\n  one.4 = s32[] constant(1)\n  \
        c.5 = f32[2]{0} constant({0.5, 0.25})\n  i2.6 = s32[] add(i.2, one.4)\n  \
        v2.7 = f32[2]{0} add(v.3, c.5)\n  \
        ROOT t.8 = (s32[], f32[2]) tuple(i2.6, v2.7)\n}\n\nENTRY main.1 {\n  \
        z.1 = s32[] constant(0)\n  v0.2 = f32[2]{0} parameter(0)\n  \
        st.3 = (s32[], f32[2]) tuple(z.1, v0.2)\n  \
        ROOT w.4 = (s32[], f32[2]) while(st.3), condition=cond.1, body=body.1\n}\n";

    #[test]
    fn counted_loop_matches_and_plans_register_map() {
        let m = parse_module(COUNTED).unwrap();
        let spec = match_counted_loop(&m, 0, 1).expect("counted loop must match");
        assert_eq!((spec.idx, spec.bound, spec.arity), (0, 4, 2));
        // body: param(0), gte i(1), gte v(2), const(3), const(4),
        // add(5), add(6), tuple(7)
        assert_eq!(spec.state_reads, vec![(1, 0), (2, 1)]);
        assert_eq!(spec.take_state, vec![true, true]);
        assert_eq!(spec.steps, vec![3, 4, 5, 6]);
        assert_eq!(spec.root_ops, vec![5, 6]);
    }

    #[test]
    fn counted_loop_rejects_near_misses() {
        // non-unit step
        let step2 = COUNTED.replace("one.4 = s32[] constant(1)", "one.4 = s32[] constant(2)");
        let m = parse_module(&step2).unwrap();
        assert!(match_counted_loop(&m, 0, 1).is_none(), "step 2 must fall back");
        // wrong compare direction
        let ge = COUNTED.replace("direction=LT", "direction=GE");
        let m = parse_module(&ge).unwrap();
        assert!(match_counted_loop(&m, 0, 1).is_none(), "GE must fall back");
        // non-constant bound (bound read from the state itself)
        let varb = COUNTED.replace(
            "n.3 = s32[] constant(4)",
            "n.3 = s32[] get-tuple-element(s.1), index=0",
        );
        let m = parse_module(&varb).unwrap();
        assert!(match_counted_loop(&m, 0, 1).is_none(), "dynamic bound must fall back");
        // counter rebound to something that is not add(counter, 1)
        let mul = COUNTED
            .replace("i2.6 = s32[] add(i.2, one.4)", "i2.6 = s32[] multiply(i.2, one.4)");
        let m = parse_module(&mul).unwrap();
        assert!(match_counted_loop(&m, 0, 1).is_none(), "multiply must fall back");
    }

    /// exp feeds both a multiply and a compare (diamond), the
    /// broadcast-of-scalar is single-use, and the select roots it all.
    const CHAIN: &str = "HloModule t\n\nENTRY main.1 {\n  x.1 = f32[4]{0} parameter(0)\n  \
        c.2 = f32[] constant(2)\n  b.3 = f32[4]{0} broadcast(c.2), dimensions={}\n  \
        e.4 = f32[4]{0} exponential(x.1)\n  m.5 = f32[4]{0} multiply(e.4, b.3)\n  \
        p.6 = pred[4]{0} compare(x.1, e.4), direction=LT\n  \
        ROOT s.7 = f32[4]{0} select(p.6, m.5, x.1)\n}\n";

    #[test]
    fn chain_matches_cone_with_diamond_and_splat() {
        let m = parse_module(CHAIN).unwrap();
        let chains = match_chains(&m.comps[m.entry]);
        assert_eq!(chains.len(), 1);
        let (root, spec) = &chains[0];
        assert_eq!(*root, 6, "select roots the chain");
        // folded broadcast (2) + multiply (4) + compare (5) are elided
        assert_eq!(spec.steps, vec![2, 4, 5]);
        // exp is multi-use -> one external slot; the splat reads the
        // broadcast's scalar source register
        assert_eq!(
            spec.inputs,
            vec![ChainInput::Full(3), ChainInput::Scalar(1), ChainInput::Full(0)]
        );
        assert_eq!(spec.take, vec![false; 3], "matcher leaves liveness to the planner");
        assert_eq!(spec.inplace, None);
        assert_eq!(
            spec.tape,
            vec![
                ops::TapeOp::Binary { op: BinaryOp::Mul, ty: ElemType::F32, a: 0, b: 1 },
                ops::TapeOp::Compare { dir: CmpDir::Lt, ty: ElemType::F32, a: 2, b: 0 },
                ops::TapeOp::Select { p: 4, t: 3, f: 2 },
            ]
        );
    }

    #[test]
    fn chain_near_misses() {
        // a multi-use broadcast stays a full input (still chains the
        // multiply+add pair, but materializes the broadcast)
        let multi = CHAIN
            .replace(
                "p.6 = pred[4]{0} compare(x.1, e.4), direction=LT",
                "p.6 = f32[4]{0} add(m.5, b.3)",
            )
            .replace("ROOT s.7 = f32[4]{0} select(p.6, m.5, x.1)", "ROOT s.7 = f32[4]{0} add(p.6, x.1)");
        let m = parse_module(&multi).unwrap();
        let chains = match_chains(&m.comps[m.entry]);
        assert_eq!(chains.len(), 1);
        let (root, spec) = &chains[0];
        // m.5 is multi-use now? no: m.5 feeds p.6 only... p.6 and s.7
        // chain; b.3 used by m.5 and p.6 -> not folded
        assert_eq!(*root, 6);
        assert!(
            spec.inputs.contains(&ChainInput::Full(2)),
            "multi-use broadcast must stay a materialized input: {:?}",
            spec.inputs
        );
        assert!(!spec.steps.contains(&2));

        // bitcast-convert is never a chain member (dtype reinterpret
        // crosses payload semantics); the chain stops at it
        const BITCAST: &str = "HloModule t\n\nENTRY main.1 {\n  \
            x.1 = u32[4]{0} parameter(0)\n  a.2 = u32[4]{0} add(x.1, x.1)\n  \
            b.3 = f32[4]{0} bitcast-convert(a.2)\n  m.4 = f32[4]{0} multiply(b.3, b.3)\n  \
            ROOT n.5 = f32[4]{0} negate(m.4)\n}\n";
        let m = parse_module(BITCAST).unwrap();
        let chains = match_chains(&m.comps[m.entry]);
        assert_eq!(chains.len(), 1);
        let (root, spec) = &chains[0];
        assert_eq!((*root, spec.steps.as_slice()), (4, &[3][..]));
        assert_eq!(spec.inputs, vec![ChainInput::Full(2)]);

        // a lone elementwise step is not worth a tape
        const LONE: &str = "HloModule t\n\nENTRY main.1 {\n  \
            x.1 = f32[4]{0} parameter(0)\n  ROOT a.2 = f32[4]{0} add(x.1, x.1)\n}\n";
        let m = parse_module(LONE).unwrap();
        assert!(match_chains(&m.comps[m.entry]).is_empty());
    }

    #[test]
    fn threefry_rejects_non_round_bodies() {
        // the counted-loop fixture bodies are nothing like a round body
        let m = parse_module(COUNTED).unwrap();
        assert!(!match_threefry(&m.comps[0]));
        assert!(!match_threefry(&m.comps[1]));
        assert!(!match_threefry(&m.comps[2]));
    }

    #[test]
    fn expected_round_tree_is_stable() {
        // the canonical tree must stay in lockstep with the kernel: a
        // quick structural sanity check of its outer spine
        let want = expected_round();
        assert_eq!(*want[3], Ex::P(4));
        assert_eq!(*want[5], Ex::P(3));
        match &*want[0] {
            Ex::Bin(BinaryOp::Add, a, b) => {
                assert_eq!(**a, Ex::P(0));
                assert_eq!(**b, Ex::Cs(1));
            }
            other => panic!("{other:?}"),
        }
        match &*want[2] {
            Ex::Bin(BinaryOp::Add, _, conv) => {
                assert!(matches!(&**conv, Ex::U32(_)));
            }
            other => panic!("{other:?}"),
        }
    }
}
