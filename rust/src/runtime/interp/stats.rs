//! Optional per-op execution histogram for the planned executor:
//! `QN_INTERP_STATS=1` makes every [`crate::runtime::interp::Plan`]
//! carry a [`Stats`] that records one (count, self-time) cell per op
//! label and prints a sorted table to stderr when the plan is dropped —
//! so "threefry dominates the grad entry" is a measured number, not
//! folklore.
//!
//! Leaf ops (elementwise kernels, the packed dot, fused reduce/scatter,
//! the native threefry call) record wall-clock self time. Ops that
//! recurse into sub-plans (`call`, generic `while`/`reduce`/`scatter`,
//! the counted-loop superinstruction) record counts only — their inner
//! steps are already timed individually, so the table never
//! double-counts a nanosecond.
//!
//! Note: in stats mode the runtime bypasses its process-wide content
//! cache ([`crate::runtime::client::Runtime::compile`]) so the plan —
//! and with it this table — drops when the runtime does.

// cells are keyed lookup during recording; the printed table is sorted
// first, so HashMap order never reaches output (clippy.toml)
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Default, Clone, Copy)]
struct Cell {
    count: u64,
    nanos: u128,
}

/// Per-plan op histogram (enabled via `QN_INTERP_STATS`).
#[derive(Debug)]
pub struct Stats {
    module: String,
    cells: Mutex<HashMap<&'static str, Cell>>,
}

impl Stats {
    /// A live collector when `QN_INTERP_STATS` is set (and not `0`).
    pub fn from_env(module: &str) -> Option<Stats> {
        match std::env::var("QN_INTERP_STATS") {
            Ok(v) if !v.is_empty() && v != "0" => Some(Stats {
                module: module.to_string(),
                cells: Mutex::new(HashMap::new()),
            }),
            _ => None,
        }
    }

    /// Record one execution of `label`; `dur` is its self time (None
    /// for recursive wrappers, which report counts only).
    pub fn record(&self, label: &'static str, dur: Option<Duration>) {
        let mut cells = self.cells.lock().unwrap();
        let c = cells.entry(label).or_default();
        c.count += 1;
        if let Some(d) = dur {
            c.nanos += d.as_nanos();
        }
    }

    /// (count, self-nanos) for one label — test/diagnostic hook.
    pub fn cell(&self, label: &str) -> Option<(u64, u128)> {
        self.cells.lock().unwrap().get(label).map(|c| (c.count, c.nanos))
    }
}

impl Drop for Stats {
    fn drop(&mut self) {
        // never panic in drop: a poisoned lock still holds valid data
        let cells = match self.cells.lock() {
            Ok(c) => c,
            Err(poisoned) => poisoned.into_inner(),
        };
        if cells.is_empty() {
            return;
        }
        let mut rows: Vec<(&str, Cell)> = cells.iter().map(|(k, v)| (*k, *v)).collect();
        rows.sort_by(|a, b| b.1.nanos.cmp(&a.1.nanos).then(b.1.count.cmp(&a.1.count)));
        let total: u128 = rows.iter().map(|(_, c)| c.nanos).sum();
        let execs: u64 = rows.iter().map(|(_, c)| c.count).sum();
        eprintln!(
            "[interp stats] {}: {} instruction executions, {:.3} ms timed",
            self.module,
            execs,
            total as f64 / 1e6
        );
        eprintln!("  {:<28} {:>12} {:>12} {:>7}", "op", "count", "self ms", "%");
        for (label, c) in rows {
            let (ms, pct) = if c.nanos == 0 {
                ("-".to_string(), "-".to_string())
            } else {
                (
                    format!("{:.3}", c.nanos as f64 / 1e6),
                    format!("{:.1}", 100.0 * c.nanos as f64 / total.max(1) as f64),
                )
            };
            eprintln!("  {label:<28} {:>12} {ms:>12} {pct:>7}", c.count);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_counts_and_self_time() {
        let st = Stats {
            module: "test".into(),
            cells: Mutex::new(HashMap::new()),
        };
        st.record("add", Some(Duration::from_nanos(100)));
        st.record("add", Some(Duration::from_nanos(50)));
        st.record("while[counted]", None);
        assert_eq!(st.cell("add"), Some((2, 150)));
        assert_eq!(st.cell("while[counted]"), Some((1, 0)));
        assert_eq!(st.cell("missing"), None);
        // drop prints to stderr without panicking
    }

    #[test]
    fn from_env_gates_on_variable() {
        // the variable is unset (or possibly set) in the test env; the
        // constructor must never panic either way
        let _ = Stats::from_env("m");
    }
}
