//! Optional per-op execution histogram for the planned executor:
//! `QN_INTERP_STATS=1` makes every [`crate::runtime::interp::Plan`]
//! carry a [`Stats`] that records one (count, self-time) cell per op
//! label and prints a sorted table to stderr when the plan is dropped —
//! so "threefry dominates the grad entry" is a measured number, not
//! folklore.
//!
//! Leaf ops (elementwise kernels, the packed dot, fused reduce/scatter,
//! the native threefry call) record wall-clock self time. Ops that
//! recurse into sub-plans (`call`, generic `while`/`reduce`/`scatter`,
//! the counted-loop superinstruction) record counts only — their inner
//! steps are already timed individually, so the table never
//! double-counts a nanosecond.
//!
//! Note: in stats mode the runtime bypasses its process-wide content
//! cache ([`crate::runtime::client::Runtime::compile`]) so the plan —
//! and with it this table — drops when the runtime does.
//!
//! This module also hosts [`Hist`], the lock-free log2-bucketed
//! histogram the serving layer reuses for per-route latency and
//! batch-size distributions (DESIGN.md §9).

// cells are keyed lookup during recording; the printed table is sorted
// first, so HashMap order never reaches output (clippy.toml)
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Default, Clone, Copy)]
struct Cell {
    count: u64,
    nanos: u128,
}

/// Per-plan op histogram (enabled via `QN_INTERP_STATS`).
#[derive(Debug)]
pub struct Stats {
    module: String,
    cells: Mutex<HashMap<&'static str, Cell>>,
}

impl Stats {
    /// A live collector when `QN_INTERP_STATS` is set (and not `0`).
    pub fn from_env(module: &str) -> Option<Stats> {
        match std::env::var("QN_INTERP_STATS") {
            Ok(v) if !v.is_empty() && v != "0" => Some(Stats {
                module: module.to_string(),
                cells: Mutex::new(HashMap::new()),
            }),
            _ => None,
        }
    }

    /// Record one execution of `label`; `dur` is its self time (None
    /// for recursive wrappers, which report counts only).
    pub fn record(&self, label: &'static str, dur: Option<Duration>) {
        let mut cells = self.cells.lock().unwrap();
        let c = cells.entry(label).or_default();
        c.count += 1;
        if let Some(d) = dur {
            c.nanos += d.as_nanos();
        }
    }

    /// (count, self-nanos) for one label — test/diagnostic hook.
    pub fn cell(&self, label: &str) -> Option<(u64, u128)> {
        self.cells.lock().unwrap().get(label).map(|c| (c.count, c.nanos))
    }
}

impl Drop for Stats {
    fn drop(&mut self) {
        // never panic in drop: a poisoned lock still holds valid data
        let cells = match self.cells.lock() {
            Ok(c) => c,
            Err(poisoned) => poisoned.into_inner(),
        };
        if cells.is_empty() {
            return;
        }
        let mut rows: Vec<(&str, Cell)> = cells.iter().map(|(k, v)| (*k, *v)).collect();
        rows.sort_by(|a, b| b.1.nanos.cmp(&a.1.nanos).then(b.1.count.cmp(&a.1.count)));
        let total: u128 = rows.iter().map(|(_, c)| c.nanos).sum();
        let execs: u64 = rows.iter().map(|(_, c)| c.count).sum();
        eprintln!(
            "[interp stats] {}: {} instruction executions, {:.3} ms timed",
            self.module,
            execs,
            total as f64 / 1e6
        );
        eprintln!("  {:<28} {:>12} {:>12} {:>7}", "op", "count", "self ms", "%");
        for (label, c) in rows {
            let (ms, pct) = if c.nanos == 0 {
                ("-".to_string(), "-".to_string())
            } else {
                (
                    format!("{:.3}", c.nanos as f64 / 1e6),
                    format!("{:.1}", 100.0 * c.nanos as f64 / total.max(1) as f64),
                )
            };
            eprintln!("  {label:<28} {:>12} {ms:>12} {pct:>7}", c.count);
        }
    }
}

// ------------------------------------------------------------ histogram ---

/// Lock-free log2-bucketed histogram of `u64` samples (latencies in
/// nanoseconds, batch sizes, queue depths). Bucket `i` holds samples
/// whose bit length is `i` (i.e. `2^(i-1) <= v < 2^i`; bucket 0 is
/// `v == 0`), so quantiles are exact to within a factor of 2 — plenty
/// for a p50/p99 serving dashboard, at the cost of three relaxed
/// atomic adds per record and zero locks on the request path.
#[derive(Debug)]
pub struct Hist {
    buckets: [AtomicU64; 65],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    fn bucket_of(v: u64) -> usize {
        (u64::BITS - v.leading_zeros()) as usize
    }

    /// Inclusive upper bound of bucket `i` (its reported quantile value).
    fn bucket_hi(i: usize) -> u64 {
        if i >= 64 { u64::MAX } else { (1u64 << i) - 1 }
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Largest sample seen, to bucket resolution (0 when empty).
    pub fn max(&self) -> u64 {
        for i in (0..65).rev() {
            if self.buckets[i].load(Ordering::Relaxed) > 0 {
                return Self::bucket_hi(i);
            }
        }
        0
    }

    /// The `q`-quantile (`0.0..=1.0`), reported as the upper bound of
    /// the bucket holding the rank-`ceil(q*count)` sample; 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for i in 0..65 {
            seen += self.buckets[i].load(Ordering::Relaxed);
            if seen >= rank {
                return Self::bucket_hi(i);
            }
        }
        Self::bucket_hi(64)
    }

    /// Non-empty `(bucket_upper_bound, count)` rows, ascending.
    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        (0..65)
            .filter_map(|i| {
                let c = self.buckets[i].load(Ordering::Relaxed);
                (c > 0).then_some((Self::bucket_hi(i), c))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_counts_and_self_time() {
        let st = Stats {
            module: "test".into(),
            cells: Mutex::new(HashMap::new()),
        };
        st.record("add", Some(Duration::from_nanos(100)));
        st.record("add", Some(Duration::from_nanos(50)));
        st.record("while[counted]", None);
        assert_eq!(st.cell("add"), Some((2, 150)));
        assert_eq!(st.cell("while[counted]"), Some((1, 0)));
        assert_eq!(st.cell("missing"), None);
        // drop prints to stderr without panicking
    }

    #[test]
    fn from_env_gates_on_variable() {
        // the variable is unset (or possibly set) in the test env; the
        // constructor must never panic either way
        let _ = Stats::from_env("m");
    }

    #[test]
    fn hist_buckets_and_quantiles() {
        let h = Hist::new();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.max(), 0);
        for v in [0u64, 1, 1, 2, 3, 900, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum(), 1907);
        // rank 4 of 7 at q=0.5 -> the sample `2`, bucket [2,4) -> hi 3
        assert_eq!(h.quantile(0.5), 3);
        // p99 -> rank 7 -> 1000, bucket [512,1024) -> hi 1023
        assert_eq!(h.quantile(0.99), 1023);
        assert_eq!(h.max(), 1023);
        assert_eq!(h.quantile(0.0), 0); // rank clamps to 1 -> sample 0
        let snap = h.snapshot();
        assert_eq!(snap.iter().map(|r| r.1).sum::<u64>(), 7);
        assert!(snap.windows(2).all(|w| w[0].0 < w[1].0));
    }

    #[test]
    fn hist_extremes() {
        let h = Hist::new();
        h.record(u64::MAX);
        h.record(1u64 << 63);
        assert_eq!(h.quantile(1.0), u64::MAX);
        assert_eq!(h.snapshot(), vec![(u64::MAX, 2)]);
    }
}
