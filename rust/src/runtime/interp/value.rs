//! Runtime values for the HLO interpreter: typed flat arrays and tuples.
//!
//! Arrays are stored in *logical row-major* order. The `{1,0}`-style
//! layout annotations in HLO text describe physical placement only and
//! never change an op's semantics, so the interpreter ignores them —
//! every index computation below works on logical dimensions.
//!
//! Buffers are reference-counted (`Arc<Buf>`, so values can cross the
//! batch-shard worker threads of DESIGN.md §4): cloning a [`Value`] is
//! O(tuple arity), `reshape` is O(1), and the planned executor
//! ([`crate::runtime::interp::plan`]) mutates buffers in place via
//! [`ArrayValue::buf_mut`] — copy-on-write, so a buffer still visible
//! through another live value is never aliased.

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

/// Element types the exported artifacts use (see DESIGN.md §4: the
/// tiny-model entry points only ever lower to these four).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemType {
    F32,
    S32,
    U32,
    Pred,
}

impl ElemType {
    pub fn parse(s: &str) -> Option<ElemType> {
        match s {
            "f32" => Some(ElemType::F32),
            "s32" => Some(ElemType::S32),
            "u32" => Some(ElemType::U32),
            "pred" => Some(ElemType::Pred),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ElemType::F32 => "f32",
            ElemType::S32 => "s32",
            ElemType::U32 => "u32",
            ElemType::Pred => "pred",
        }
    }
}

/// The shape of one instruction result: an array or a tuple of shapes.
#[derive(Debug, Clone, PartialEq)]
pub enum Shape {
    Array { ty: ElemType, dims: Vec<usize> },
    Tuple(Vec<Shape>),
}

impl Shape {
    pub fn numel(&self) -> usize {
        match self {
            Shape::Array { dims, .. } => dims.iter().product(),
            Shape::Tuple(_) => 0,
        }
    }

    pub fn array(&self) -> Result<(ElemType, &[usize])> {
        match self {
            Shape::Array { ty, dims } => Ok((*ty, dims)),
            Shape::Tuple(_) => bail!("expected array shape, got tuple"),
        }
    }
}

/// Typed flat element storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Buf {
    F32(Vec<f32>),
    S32(Vec<i32>),
    U32(Vec<u32>),
    Pred(Vec<bool>),
}

impl Buf {
    pub fn ty(&self) -> ElemType {
        match self {
            Buf::F32(_) => ElemType::F32,
            Buf::S32(_) => ElemType::S32,
            Buf::U32(_) => ElemType::U32,
            Buf::Pred(_) => ElemType::Pred,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::S32(v) => v.len(),
            Buf::U32(v) => v.len(),
            Buf::Pred(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn with_capacity(ty: ElemType, n: usize) -> Buf {
        match ty {
            ElemType::F32 => Buf::F32(Vec::with_capacity(n)),
            ElemType::S32 => Buf::S32(Vec::with_capacity(n)),
            ElemType::U32 => Buf::U32(Vec::with_capacity(n)),
            ElemType::Pred => Buf::Pred(Vec::with_capacity(n)),
        }
    }

    /// Append `src[i]` to `self` (same element type required).
    pub fn push_from(&mut self, src: &Buf, i: usize) {
        match (self, src) {
            (Buf::F32(d), Buf::F32(s)) => d.push(s[i]),
            (Buf::S32(d), Buf::S32(s)) => d.push(s[i]),
            (Buf::U32(d), Buf::U32(s)) => d.push(s[i]),
            (Buf::Pred(d), Buf::Pred(s)) => d.push(s[i]),
            (d, s) => panic!("push_from type mismatch: {:?} vs {:?}", d.ty(), s.ty()),
        }
    }

    /// Overwrite `self[di]` with `src[si]` (same element type required).
    pub fn set_from(&mut self, di: usize, src: &Buf, si: usize) {
        match (self, src) {
            (Buf::F32(d), Buf::F32(s)) => d[di] = s[si],
            (Buf::S32(d), Buf::S32(s)) => d[di] = s[si],
            (Buf::U32(d), Buf::U32(s)) => d[di] = s[si],
            (Buf::Pred(d), Buf::Pred(s)) => d[di] = s[si],
            (d, s) => panic!("set_from type mismatch: {:?} vs {:?}", d.ty(), s.ty()),
        }
    }

    /// Element `i` as an index (integer types only).
    pub fn index_at(&self, i: usize) -> Result<i64> {
        match self {
            Buf::S32(v) => Ok(v[i] as i64),
            Buf::U32(v) => Ok(v[i] as i64),
            other => bail!("index element must be integer, got {}", other.ty().name()),
        }
    }

    /// Copy of the element range `[lo, hi)` (batch-shard slicing).
    pub fn copy_range(&self, lo: usize, hi: usize) -> Buf {
        match self {
            Buf::F32(v) => Buf::F32(v[lo..hi].to_vec()),
            Buf::S32(v) => Buf::S32(v[lo..hi].to_vec()),
            Buf::U32(v) => Buf::U32(v[lo..hi].to_vec()),
            Buf::Pred(v) => Buf::Pred(v[lo..hi].to_vec()),
        }
    }

    /// Mutable flat u32 lane view (the threefry kernel writes lanes in
    /// place; pair with [`ArrayValue::buf_mut`] for copy-on-write).
    pub fn as_u32_mut(&mut self) -> Result<&mut [u32]> {
        match self {
            Buf::U32(v) => Ok(v),
            other => bail!("expected u32 array, got {}", other.ty().name()),
        }
    }

    /// `n` copies of `self[i]` (scalar-broadcast fast path).
    pub fn splat(&self, i: usize, n: usize) -> Buf {
        match self {
            Buf::F32(v) => Buf::F32(vec![v[i]; n]),
            Buf::S32(v) => Buf::S32(vec![v[i]; n]),
            Buf::U32(v) => Buf::U32(vec![v[i]; n]),
            Buf::Pred(v) => Buf::Pred(vec![v[i]; n]),
        }
    }
}

/// A typed n-dimensional array: flat row-major data behind a shared,
/// copy-on-write buffer, plus logical dims.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayValue {
    pub dims: Vec<usize>,
    pub buf: Arc<Buf>,
}

impl ArrayValue {
    pub fn new(dims: Vec<usize>, buf: Buf) -> Result<ArrayValue> {
        ArrayValue::from_shared(dims, Arc::new(buf))
    }

    /// Build from an already-shared buffer (O(1) reshape/view paths).
    pub fn from_shared(dims: Vec<usize>, buf: Arc<Buf>) -> Result<ArrayValue> {
        let numel: usize = dims.iter().product();
        ensure!(
            buf.len() == numel,
            "array data length {} does not match dims {:?}",
            buf.len(),
            dims
        );
        Ok(ArrayValue { dims, buf })
    }

    pub fn f32(dims: &[usize], data: Vec<f32>) -> Result<ArrayValue> {
        ArrayValue::new(dims.to_vec(), Buf::F32(data))
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> Result<ArrayValue> {
        ArrayValue::new(dims.to_vec(), Buf::S32(data))
    }

    pub fn scalar_f32(v: f32) -> ArrayValue {
        ArrayValue { dims: vec![], buf: Arc::new(Buf::F32(vec![v])) }
    }

    pub fn ty(&self) -> ElemType {
        self.buf.ty()
    }

    pub fn numel(&self) -> usize {
        self.buf.len()
    }

    /// Mutable access to the buffer, cloning first if it is shared
    /// (copy-on-write): in-place execution can never corrupt a buffer
    /// another live value still sees.
    pub fn buf_mut(&mut self) -> &mut Buf {
        Arc::make_mut(&mut self.buf)
    }

    /// Whether this value is the buffer's only owner (a mutation would
    /// run in place rather than copy). Diagnostic/test hook.
    pub fn is_unique(&self) -> bool {
        Arc::strong_count(&self.buf) == 1
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &*self.buf {
            Buf::F32(v) => Ok(v),
            other => bail!("expected f32 array, got {}", other.ty().name()),
        }
    }

    /// Flat u32 lane view (the threefry kernel's input shape).
    pub fn as_u32(&self) -> Result<&[u32]> {
        match &*self.buf {
            Buf::U32(v) => Ok(v),
            other => bail!("expected u32 array, got {}", other.ty().name()),
        }
    }

    pub fn as_pred(&self) -> Result<&[bool]> {
        match &*self.buf {
            Buf::Pred(v) => Ok(v),
            other => bail!("expected pred array, got {}", other.ty().name()),
        }
    }

    /// One element as a scalar (rank-0) array of the same type.
    pub fn scalar_at(&self, i: usize) -> ArrayValue {
        let mut buf = Buf::with_capacity(self.ty(), 1);
        buf.push_from(&self.buf, i);
        ArrayValue { dims: vec![], buf: Arc::new(buf) }
    }
}

/// An instruction result: array or tuple.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Array(ArrayValue),
    Tuple(Vec<Value>),
}

impl Value {
    pub fn array(&self) -> Result<&ArrayValue> {
        match self {
            Value::Array(a) => Ok(a),
            Value::Tuple(_) => bail!("expected array value, got tuple"),
        }
    }

    pub fn into_array(self) -> Result<ArrayValue> {
        match self {
            Value::Array(a) => Ok(a),
            Value::Tuple(_) => bail!("expected array value, got tuple"),
        }
    }

    pub fn tuple(&self) -> Result<&[Value]> {
        match self {
            Value::Tuple(vs) => Ok(vs),
            Value::Array(_) => bail!("expected tuple value, got array"),
        }
    }

    pub fn pred_scalar(&self) -> Result<bool> {
        let a = self.array()?;
        ensure!(a.numel() == 1, "expected scalar pred");
        Ok(a.as_pred()?[0])
    }
}

// ----------------------------------------------------- index helpers ---

/// Row-major strides for `dims`.
pub fn strides_of(dims: &[usize]) -> Vec<usize> {
    let mut st = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        st[i] = st[i + 1] * dims[i + 1];
    }
    st
}

/// Decompose flat index `f` into per-dimension coordinates.
pub fn unflatten(mut f: usize, strides: &[usize], out: &mut [usize]) {
    for (o, &s) in out.iter_mut().zip(strides) {
        *o = f / s;
        f %= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(strides_of(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides_of(&[5]), vec![1]);
        assert!(strides_of(&[]).is_empty());
    }

    #[test]
    fn unflatten_roundtrip() {
        let dims = [2usize, 3, 4];
        let st = strides_of(&dims);
        let mut idx = [0usize; 3];
        unflatten(17, &st, &mut idx);
        assert_eq!(idx, [1, 1, 1]);
        let back: usize = idx.iter().zip(&st).map(|(&i, &s)| i * s).sum();
        assert_eq!(back, 17);
    }

    #[test]
    fn array_value_validates_length() {
        assert!(ArrayValue::f32(&[2, 2], vec![0.0; 4]).is_ok());
        assert!(ArrayValue::f32(&[2, 2], vec![0.0; 3]).is_err());
        // rank-0 scalar holds exactly one element
        assert!(ArrayValue::f32(&[], vec![1.0]).is_ok());
        assert!(ArrayValue::f32(&[], vec![]).is_err());
    }

    #[test]
    fn buf_push_and_set() {
        let src = Buf::S32(vec![10, 20, 30]);
        let mut dst = Buf::with_capacity(ElemType::S32, 2);
        dst.push_from(&src, 2);
        dst.push_from(&src, 0);
        assert_eq!(dst, Buf::S32(vec![30, 10]));
        dst.set_from(1, &src, 1);
        assert_eq!(dst, Buf::S32(vec![30, 20]));
        assert_eq!(src.index_at(1).unwrap(), 20);
    }

    #[test]
    fn buf_range_and_splat() {
        let src = Buf::S32(vec![10, 20, 30, 40]);
        assert_eq!(src.copy_range(1, 3), Buf::S32(vec![20, 30]));
        assert_eq!(src.splat(2, 3), Buf::S32(vec![30, 30, 30]));
    }

    #[test]
    fn scalar_at_extracts_typed_scalar() {
        let a = ArrayValue::f32(&[3], vec![1.5, 2.5, 3.5]).unwrap();
        let s = a.scalar_at(1);
        assert!(s.dims.is_empty());
        assert_eq!(s.as_f32().unwrap(), &[2.5]);
    }

    #[test]
    fn copy_on_write_preserves_shared_buffers() {
        let a = ArrayValue::f32(&[2], vec![1.0, 2.0]).unwrap();
        let mut b = a.clone();
        assert!(!b.is_unique());
        if let Buf::F32(v) = b.buf_mut() {
            v[0] = 9.0;
        }
        // the original is untouched; b now owns its buffer
        assert_eq!(a.as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(b.as_f32().unwrap(), &[9.0, 2.0]);
        assert!(b.is_unique() && a.is_unique());
    }

    #[test]
    fn reshape_shares_storage() {
        let a = ArrayValue::f32(&[2, 2], vec![0.0; 4]).unwrap();
        let b = ArrayValue::from_shared(vec![4], a.buf.clone()).unwrap();
        assert!(Arc::ptr_eq(&a.buf, &b.buf));
        assert!(ArrayValue::from_shared(vec![3], a.buf.clone()).is_err());
    }

    #[test]
    fn value_accessors() {
        let a = Value::Array(ArrayValue::new(vec![], Buf::Pred(vec![true])).unwrap());
        assert!(a.pred_scalar().unwrap());
        assert!(a.tuple().is_err());
        let t = Value::Tuple(vec![a.clone()]);
        assert_eq!(t.tuple().unwrap().len(), 1);
        assert!(t.array().is_err());
    }
}
