//! Parser for the HLO *text* format that `python/compile/aot.py` emits
//! (`HloModule` header, named computations, one instruction per line).
//!
//! The grammar subset matches what jax 0.4.x lowers the tiny models to —
//! see DESIGN.md §4 for the op inventory. Layout annotations (`{1,0}`)
//! are consumed and ignored (physical-only); `/*index=N*/`-style
//! comments are treated as whitespace. Instruction operands always
//! refer to earlier instructions of the same computation; computations
//! referenced by `to_apply`/`condition`/`body` are resolved module-wide
//! in a fixup pass after all computations have been parsed.

// name→index maps are keyed lookup only; instruction and computation
// order always comes from the source text, never map iteration
// (clippy.toml bans HashMap in order-defining paths)
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use crate::runtime::interp::value::{ArrayValue, Buf, ElemType, Shape};

// ------------------------------------------------------------- model ---

/// Comparison directions (`compare(..), direction=LT`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpDir {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnaryOp {
    Negate,
    Exp,
    Log,
    Rsqrt,
    Sine,
    Cosine,
    RoundNearestEven,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinaryOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
    And,
    Or,
    Xor,
    Shl,
    ShrLogical,
}

/// `dot` dimension numbers.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DotDims {
    pub lhs_batch: Vec<usize>,
    pub rhs_batch: Vec<usize>,
    pub lhs_contracting: Vec<usize>,
    pub rhs_contracting: Vec<usize>,
}

/// One dimension of a `convolution`/`reduce-window` window
/// (`window={size=3x3 stride=2x2 pad=1_1x1_1 lhs_dilate=2x2}`); fields
/// the HLO text omits take their XLA defaults (stride 1, pad 0, both
/// dilations 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowDim {
    pub size: usize,
    pub stride: usize,
    pub pad_lo: i64,
    pub pad_hi: i64,
    /// lhs (input) dilation — `lhs_dilate`.
    pub base_dilation: usize,
    /// rhs (kernel) dilation — `rhs_dilate`.
    pub window_dilation: usize,
}

impl WindowDim {
    /// Output extent of this dimension for input extent `n` (XLA's
    /// convolution shape rule, shared with `reduce-window`).
    pub fn out_size(&self, n: usize) -> usize {
        let dilated = if n == 0 { 0 } else { (n - 1) as i64 * self.base_dilation as i64 + 1 };
        let window = (self.size as i64 - 1) * self.window_dilation as i64 + 1;
        let padded = dilated + self.pad_lo + self.pad_hi;
        if padded < window {
            0
        } else {
            ((padded - window) / self.stride as i64) as usize + 1
        }
    }
}

/// `convolution` dimension numbers, parsed from
/// `dim_labels=b01f_01io->b01f` plus the window and group counts.
/// `*_spatial[k]` is the tensor dimension holding spatial dim `k`.
#[derive(Debug, Clone, PartialEq)]
pub struct ConvDims {
    pub window: Vec<WindowDim>,
    pub lhs_batch: usize,
    pub lhs_feature: usize,
    pub lhs_spatial: Vec<usize>,
    pub rhs_input: usize,
    pub rhs_output: usize,
    pub rhs_spatial: Vec<usize>,
    pub out_batch: usize,
    pub out_feature: usize,
    pub out_spatial: Vec<usize>,
    pub feature_groups: usize,
    pub batch_groups: usize,
}

/// `gather` dimension numbers (StableHLO semantics, incl. batching dims).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GatherDims {
    pub offset_dims: Vec<usize>,
    pub collapsed_slice_dims: Vec<usize>,
    pub operand_batching_dims: Vec<usize>,
    pub start_indices_batching_dims: Vec<usize>,
    pub start_index_map: Vec<usize>,
    pub index_vector_dim: usize,
    pub slice_sizes: Vec<usize>,
}

/// `scatter` dimension numbers (StableHLO semantics, incl. batching dims).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScatterDims {
    pub update_window_dims: Vec<usize>,
    pub inserted_window_dims: Vec<usize>,
    pub input_batching_dims: Vec<usize>,
    pub scatter_indices_batching_dims: Vec<usize>,
    pub scatter_dims_to_operand_dims: Vec<usize>,
    pub index_vector_dim: usize,
}

/// One parsed instruction's operation, with attributes already typed.
/// Computation references start as `usize::MAX` and are patched by the
/// module-level fixup pass.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    Parameter(usize),
    Constant(ArrayValue),
    Tuple,
    GetTupleElement(usize),
    Call { comp: usize },
    While { cond: usize, body: usize },
    Iota { dim: usize },
    Broadcast { dims: Vec<usize> },
    Reshape,
    Transpose { perm: Vec<usize> },
    /// Per output dimension: (start, limit, stride).
    Slice { spec: Vec<(usize, usize, usize)> },
    Concatenate { dim: usize },
    Select,
    Compare { dir: CmpDir },
    Convert,
    BitcastConvert,
    Unary(UnaryOp),
    Binary(BinaryOp),
    Dot(DotDims),
    Reduce { dims: Vec<usize>, comp: usize },
    Gather(GatherDims),
    Scatter { dims: ScatterDims, comp: usize },
    Convolution(ConvDims),
    Reverse { dims: Vec<usize> },
    ReduceWindow { window: Vec<WindowDim>, comp: usize },
}

#[derive(Debug, Clone)]
pub struct Instr {
    pub name: String,
    pub shape: Shape,
    pub op: Op,
    /// Indices of operand instructions within the same computation.
    pub operands: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Computation {
    pub name: String,
    pub instrs: Vec<Instr>,
    pub root: usize,
    pub n_params: usize,
}

#[derive(Debug, Clone)]
pub struct HloModule {
    pub name: String,
    pub comps: Vec<Computation>,
    pub entry: usize,
}

impl HloModule {
    pub fn entry_computation(&self) -> &Computation {
        &self.comps[self.entry]
    }

    pub fn parse_str(text: &str) -> Result<HloModule> {
        parse_module(text)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<HloModule> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading HLO text {}", path.display()))?;
        parse_module(&text).with_context(|| format!("parsing {}", path.display()))
    }
}

// ------------------------------------------------------------ cursor ---

struct Cursor<'a> {
    s: &'a str,
    i: usize,
}

impl<'a> Cursor<'a> {
    fn new(s: &'a str) -> Cursor<'a> {
        Cursor { s, i: 0 }
    }

    fn eof(&self) -> bool {
        self.i >= self.s.len()
    }

    fn peek(&self) -> u8 {
        if self.eof() {
            0
        } else {
            self.s.as_bytes()[self.i]
        }
    }

    fn context(&self) -> &str {
        let end = (self.i + 40).min(self.s.len());
        &self.s[self.i..end]
    }

    /// Skip spaces/tabs (and newlines when `nl`), plus `/* ... */`.
    fn skip_ws(&mut self, nl: bool) -> Result<()> {
        loop {
            match self.peek() {
                b' ' | b'\t' => self.i += 1,
                b'\r' | b'\n' if nl => self.i += 1,
                b'/' if self.s[self.i..].starts_with("/*") => {
                    match self.s[self.i + 2..].find("*/") {
                        Some(j) => self.i += 2 + j + 2,
                        None => bail!("unterminated /* comment"),
                    }
                }
                _ => return Ok(()),
            }
        }
    }

    fn try_eat(&mut self, tok: &str) -> bool {
        if self.s[self.i..].starts_with(tok) {
            self.i += tok.len();
            true
        } else {
            false
        }
    }

    fn eat(&mut self, tok: &str) -> Result<()> {
        ensure!(self.try_eat(tok), "expected '{tok}' at '{}…'", self.context());
        Ok(())
    }

    /// HLO identifier: letters, digits, `_`, `.`, `-` (opcode and
    /// instruction names like `shift-right-logical.12`).
    fn ident(&mut self) -> Result<&'a str> {
        let start = self.i;
        while !self.eof() {
            let c = self.peek();
            if c.is_ascii_alphanumeric() || c == b'_' || c == b'.' || c == b'-' {
                self.i += 1;
            } else {
                break;
            }
        }
        ensure!(self.i > start, "expected identifier at '{}…'", self.context());
        Ok(&self.s[start..self.i])
    }

    /// Scan to the next top-level occurrence of a stop byte (or a `}`
    /// closing an outer brace), tracking `{}` nesting.
    fn scan_until(&mut self, stops: &[u8]) -> &'a str {
        let start = self.i;
        let mut depth = 0usize;
        while !self.eof() {
            let c = self.peek();
            if c == b'{' {
                depth += 1;
            } else if c == b'}' {
                if depth == 0 {
                    break;
                }
                depth -= 1;
            } else if depth == 0 && stops.contains(&c) {
                break;
            }
            self.i += 1;
        }
        &self.s[start..self.i]
    }
}

// ------------------------------------------------------- sub-parsers ---

fn parse_shape(c: &mut Cursor) -> Result<Shape> {
    c.skip_ws(true)?;
    if c.try_eat("(") {
        let mut elems = Vec::new();
        loop {
            c.skip_ws(true)?;
            if c.try_eat(")") {
                break;
            }
            elems.push(parse_shape(c)?);
            c.skip_ws(true)?;
            c.try_eat(",");
        }
        return Ok(Shape::Tuple(elems));
    }
    let tyname = c.ident()?;
    let ty = ElemType::parse(tyname)
        .with_context(|| format!("unsupported element type '{tyname}'"))?;
    c.eat("[")?;
    let mut dims = Vec::new();
    loop {
        c.skip_ws(true)?;
        if c.try_eat("]") {
            break;
        }
        let tok = c.scan_until(b",]");
        let tok = tok.trim();
        if !tok.is_empty() {
            dims.push(tok.parse::<usize>().with_context(|| format!("bad dim '{tok}'"))?);
        }
        c.try_eat(",");
    }
    // optional physical layout `{1,0}` — ignored (logical row-major)
    c.skip_ws(false)?;
    if c.peek() == b'{' {
        c.eat("{")?;
        c.scan_until(b"");
        c.eat("}")?;
    }
    Ok(Shape::Array { ty, dims })
}

/// Parse a `constant(...)` literal payload into a flat row-major buffer.
fn parse_literal(c: &mut Cursor, ty: ElemType, numel: usize) -> Result<Buf> {
    let mut buf = Buf::with_capacity(ty, numel);
    parse_literal_nested(c, ty, &mut buf)?;
    ensure!(buf.len() == numel, "constant literal has {} elements, shape wants {numel}", buf.len());
    Ok(buf)
}

fn parse_literal_nested(c: &mut Cursor, ty: ElemType, out: &mut Buf) -> Result<()> {
    c.skip_ws(true)?;
    if c.try_eat("{") {
        loop {
            c.skip_ws(true)?;
            if c.try_eat("}") {
                return Ok(());
            }
            parse_literal_nested(c, ty, out)?;
            c.skip_ws(true)?;
            c.try_eat(",");
        }
    }
    let tok = c.scan_until(b",)").trim();
    match (ty, out) {
        (ElemType::F32, Buf::F32(v)) => {
            v.push(tok.parse::<f32>().with_context(|| format!("bad f32 literal '{tok}'"))?)
        }
        (ElemType::S32, Buf::S32(v)) => {
            v.push(tok.parse::<i32>().with_context(|| format!("bad s32 literal '{tok}'"))?)
        }
        (ElemType::U32, Buf::U32(v)) => {
            v.push(tok.parse::<u32>().with_context(|| format!("bad u32 literal '{tok}'"))?)
        }
        (ElemType::Pred, Buf::Pred(v)) => match tok {
            "true" | "1" => v.push(true),
            "false" | "0" => v.push(false),
            _ => bail!("bad pred literal '{tok}'"),
        },
        _ => unreachable!("literal buffer type mismatch"),
    }
    Ok(())
}

fn int_list(s: &str) -> Result<Vec<usize>> {
    let s = s.trim().trim_start_matches('{').trim_end_matches('}').trim();
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|x| x.trim().parse::<usize>().with_context(|| format!("bad int list '{s}'")))
        .collect()
}

/// `{[0:1], [2:8:2]}` → per-dimension (start, limit, stride).
fn parse_slice_spec(s: &str) -> Result<Vec<(usize, usize, usize)>> {
    let mut out = Vec::new();
    for part in s.trim().trim_start_matches('{').trim_end_matches('}').split(']') {
        let part = part.trim().trim_start_matches(',').trim().trim_start_matches('[');
        if part.is_empty() {
            continue;
        }
        let nums: Vec<usize> = part
            .split(':')
            .map(|x| x.trim().parse::<usize>().with_context(|| format!("bad slice '{part}'")))
            .collect::<Result<_>>()?;
        match nums.len() {
            2 => out.push((nums[0], nums[1], 1)),
            3 => out.push((nums[0], nums[1], nums[2])),
            _ => bail!("bad slice spec '{part}'"),
        }
    }
    Ok(out)
}

/// `{size=3x3 stride=2x2 pad=1_1x1_1 lhs_dilate=2x2 rhs_dilate=2x2}` —
/// per-dimension window spec; fields absent from the text default to
/// stride 1, pad 0_0, dilations 1 (the HLO printer omits defaults,
/// e.g. `window={size=16x16}`).
fn parse_window_attr(s: &str) -> Result<Vec<WindowDim>> {
    let body = s.trim().trim_start_matches('{').trim_end_matches('}').trim();
    let mut size: Vec<usize> = Vec::new();
    let mut stride: Vec<usize> = Vec::new();
    let mut pad: Vec<(i64, i64)> = Vec::new();
    let mut base: Vec<usize> = Vec::new();
    let mut wdil: Vec<usize> = Vec::new();
    for field in body.split_whitespace() {
        let (key, val) =
            field.split_once('=').with_context(|| format!("bad window field '{field}'"))?;
        let parts: Result<Vec<usize>> = val
            .split('x')
            .map(|p| p.parse::<usize>().with_context(|| format!("bad window value '{val}'")))
            .collect();
        match key {
            "size" => size = parts?,
            "stride" => stride = parts?,
            "lhs_dilate" => base = parts?,
            "rhs_dilate" => wdil = parts?,
            "pad" => {
                pad = val
                    .split('x')
                    .map(|p| {
                        let (lo, hi) =
                            p.split_once('_').with_context(|| format!("bad pad '{p}'"))?;
                        Ok((lo.parse::<i64>()?, hi.parse::<i64>()?))
                    })
                    .collect::<Result<_>>()?
            }
            other => bail!("unknown window field '{other}'"),
        }
    }
    ensure!(!size.is_empty(), "window spec has no size field");
    let nd = size.len();
    for (name, len) in [
        ("stride", stride.len()),
        ("pad", pad.len()),
        ("lhs_dilate", base.len()),
        ("rhs_dilate", wdil.len()),
    ] {
        ensure!(len == 0 || len == nd, "window {name} rank mismatch");
    }
    Ok((0..nd)
        .map(|d| WindowDim {
            size: size[d],
            stride: stride.get(d).copied().unwrap_or(1),
            pad_lo: pad.get(d).map_or(0, |p| p.0),
            pad_hi: pad.get(d).map_or(0, |p| p.1),
            base_dilation: base.get(d).copied().unwrap_or(1),
            window_dilation: wdil.get(d).copied().unwrap_or(1),
        })
        .collect())
}

/// One part of `dim_labels` (`b01f`): positions of the two letter dims
/// and, per spatial number `k`, the tensor dim holding it.
fn parse_label_part(part: &str, a_ch: u8, b_ch: u8) -> Result<(usize, usize, Vec<usize>)> {
    ensure!(part.len() >= 2, "bad dim_labels part '{part}'");
    let mut a_pos = None;
    let mut b_pos = None;
    let mut spatial = vec![usize::MAX; part.len() - 2];
    for (pos, ch) in part.bytes().enumerate() {
        if ch == a_ch {
            ensure!(a_pos.is_none(), "duplicate '{}' in '{part}'", a_ch as char);
            a_pos = Some(pos);
        } else if ch == b_ch {
            ensure!(b_pos.is_none(), "duplicate '{}' in '{part}'", b_ch as char);
            b_pos = Some(pos);
        } else {
            let k = (ch as char).to_digit(10).with_context(|| {
                format!("bad dim_labels char '{}' in '{part}'", ch as char)
            })? as usize;
            ensure!(
                k < spatial.len() && spatial[k] == usize::MAX,
                "bad spatial dim {k} in '{part}'"
            );
            spatial[k] = pos;
        }
    }
    Ok((
        a_pos.with_context(|| format!("missing '{}' in '{part}'", a_ch as char))?,
        b_pos.with_context(|| format!("missing '{}' in '{part}'", b_ch as char))?,
        spatial,
    ))
}

fn parse_conv_dims(attrs: &Attrs) -> Result<ConvDims> {
    let labels = attrs.req("dim_labels")?;
    let (lhs, rest) = labels.split_once('_').context("bad dim_labels (no '_')")?;
    let (rhs, out) = rest.split_once("->").context("bad dim_labels (no '->')")?;
    let (lhs_batch, lhs_feature, lhs_spatial) = parse_label_part(lhs, b'b', b'f')?;
    let (rhs_input, rhs_output, rhs_spatial) = parse_label_part(rhs, b'i', b'o')?;
    let (out_batch, out_feature, out_spatial) = parse_label_part(out, b'b', b'f')?;
    let window = parse_window_attr(attrs.req("window")?)?;
    ensure!(
        window.len() == lhs_spatial.len()
            && rhs_spatial.len() == lhs_spatial.len()
            && out_spatial.len() == lhs_spatial.len(),
        "convolution window/dim_labels rank mismatch"
    );
    let group = |key| -> Result<usize> {
        match attrs.get(key) {
            Some(v) => {
                let g = v.trim().parse::<usize>().with_context(|| format!("bad {key}"))?;
                ensure!(g >= 1, "{key} must be >= 1");
                Ok(g)
            }
            None => Ok(1),
        }
    };
    Ok(ConvDims {
        window,
        lhs_batch,
        lhs_feature,
        lhs_spatial,
        rhs_input,
        rhs_output,
        rhs_spatial,
        out_batch,
        out_feature,
        out_spatial,
        feature_groups: group("feature_group_count")?,
        batch_groups: group("batch_group_count")?,
    })
}

// -------------------------------------------------------- attributes ---

/// Raw `key=value` attributes of one instruction line.
struct Attrs<'a> {
    kv: Vec<(&'a str, &'a str)>,
}

impl<'a> Attrs<'a> {
    fn get(&self, key: &str) -> Option<&'a str> {
        self.kv.iter().find(|(k, _)| *k == key).map(|(_, v)| *v)
    }

    fn req(&self, key: &str) -> Result<&'a str> {
        self.get(key).with_context(|| format!("missing attribute '{key}'"))
    }

    fn ints(&self, key: &str) -> Result<Vec<usize>> {
        match self.get(key) {
            Some(v) => int_list(v),
            None => Ok(Vec::new()),
        }
    }

    fn int(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .trim()
            .parse::<usize>()
            .with_context(|| format!("bad integer attribute '{key}'"))
    }
}

fn parse_attrs<'a>(c: &mut Cursor<'a>) -> Result<Attrs<'a>> {
    let mut kv = Vec::new();
    loop {
        c.skip_ws(false)?;
        let save = c.i;
        if !c.try_eat(",") {
            break;
        }
        c.skip_ws(false)?;
        // a line break inside the operand list would land here; only
        // `ident=` continues the attribute list
        let Ok(key) = c.ident() else {
            c.i = save;
            break;
        };
        if !c.try_eat("=") {
            c.i = save;
            break;
        }
        c.skip_ws(false)?;
        let val = if c.peek() == b'{' {
            let start = c.i;
            c.eat("{")?;
            c.scan_until(b"");
            c.eat("}")?;
            &c.s[start..c.i]
        } else {
            c.scan_until(b",\n").trim()
        };
        kv.push((key, val));
    }
    Ok(Attrs { kv })
}

// ------------------------------------------------------ instructions ---

/// Pending computation-name reference to patch after the whole module
/// is parsed: (computation idx, instruction idx, slot, name).
enum FixSlot {
    Call,
    WhileCond,
    WhileBody,
    Reduce,
    Scatter,
    ReduceWindow,
}

struct Fixup {
    comp: usize,
    instr: usize,
    slot: FixSlot,
    target: String,
}

fn build_op(
    opcode: &str,
    shape: &Shape,
    attrs: &Attrs,
    literal: Option<Buf>,
    param_num: Option<usize>,
    fix: &mut Vec<(FixSlot, String)>,
) -> Result<Op> {
    let comp_ref = |fix: &mut Vec<(FixSlot, String)>, slot: FixSlot, name: &str| {
        fix.push((slot, name.to_string()));
        usize::MAX
    };
    Ok(match opcode {
        "parameter" => Op::Parameter(param_num.context("parameter without number")?),
        "constant" => {
            let (ty, dims) = shape.array()?;
            let buf = literal.context("constant without literal")?;
            ensure!(buf.ty() == ty, "constant literal type mismatch");
            Op::Constant(ArrayValue::new(dims.to_vec(), buf)?)
        }
        "tuple" => Op::Tuple,
        "get-tuple-element" => Op::GetTupleElement(attrs.int("index")?),
        "call" => Op::Call { comp: comp_ref(fix, FixSlot::Call, attrs.req("to_apply")?) },
        "while" => {
            let cond = comp_ref(fix, FixSlot::WhileCond, attrs.req("condition")?);
            let body = comp_ref(fix, FixSlot::WhileBody, attrs.req("body")?);
            Op::While { cond, body }
        }
        "iota" => Op::Iota { dim: attrs.int("iota_dimension")? },
        "broadcast" => Op::Broadcast { dims: attrs.ints("dimensions")? },
        "reshape" => Op::Reshape,
        "transpose" => Op::Transpose { perm: attrs.ints("dimensions")? },
        "slice" => Op::Slice { spec: parse_slice_spec(attrs.req("slice")?)? },
        "concatenate" => {
            let dims = attrs.ints("dimensions")?;
            ensure!(dims.len() == 1, "concatenate needs exactly one dimension");
            Op::Concatenate { dim: dims[0] }
        }
        "select" => Op::Select,
        "compare" => {
            let dir = match attrs.req("direction")? {
                "EQ" => CmpDir::Eq,
                "NE" => CmpDir::Ne,
                "LT" => CmpDir::Lt,
                "LE" => CmpDir::Le,
                "GT" => CmpDir::Gt,
                "GE" => CmpDir::Ge,
                other => bail!("unknown compare direction '{other}'"),
            };
            Op::Compare { dir }
        }
        "convert" => Op::Convert,
        "bitcast-convert" => Op::BitcastConvert,
        "negate" => Op::Unary(UnaryOp::Negate),
        "exponential" => Op::Unary(UnaryOp::Exp),
        "log" => Op::Unary(UnaryOp::Log),
        "rsqrt" => Op::Unary(UnaryOp::Rsqrt),
        "sine" => Op::Unary(UnaryOp::Sine),
        "cosine" => Op::Unary(UnaryOp::Cosine),
        "round-nearest-even" => Op::Unary(UnaryOp::RoundNearestEven),
        "add" => Op::Binary(BinaryOp::Add),
        "subtract" => Op::Binary(BinaryOp::Sub),
        "multiply" => Op::Binary(BinaryOp::Mul),
        "divide" => Op::Binary(BinaryOp::Div),
        "maximum" => Op::Binary(BinaryOp::Max),
        "minimum" => Op::Binary(BinaryOp::Min),
        "power" => Op::Binary(BinaryOp::Pow),
        "and" => Op::Binary(BinaryOp::And),
        "or" => Op::Binary(BinaryOp::Or),
        "xor" => Op::Binary(BinaryOp::Xor),
        "shift-left" => Op::Binary(BinaryOp::Shl),
        "shift-right-logical" => Op::Binary(BinaryOp::ShrLogical),
        "dot" => Op::Dot(DotDims {
            lhs_batch: attrs.ints("lhs_batch_dims")?,
            rhs_batch: attrs.ints("rhs_batch_dims")?,
            lhs_contracting: attrs.ints("lhs_contracting_dims")?,
            rhs_contracting: attrs.ints("rhs_contracting_dims")?,
        }),
        "reduce" => Op::Reduce {
            dims: attrs.ints("dimensions")?,
            comp: comp_ref(fix, FixSlot::Reduce, attrs.req("to_apply")?),
        },
        "gather" => Op::Gather(GatherDims {
            offset_dims: attrs.ints("offset_dims")?,
            collapsed_slice_dims: attrs.ints("collapsed_slice_dims")?,
            operand_batching_dims: attrs.ints("operand_batching_dims")?,
            start_indices_batching_dims: attrs.ints("start_indices_batching_dims")?,
            start_index_map: attrs.ints("start_index_map")?,
            index_vector_dim: attrs.int("index_vector_dim")?,
            slice_sizes: attrs.ints("slice_sizes")?,
        }),
        "scatter" => Op::Scatter {
            dims: ScatterDims {
                update_window_dims: attrs.ints("update_window_dims")?,
                inserted_window_dims: attrs.ints("inserted_window_dims")?,
                input_batching_dims: attrs.ints("input_batching_dims")?,
                scatter_indices_batching_dims: attrs.ints("scatter_indices_batching_dims")?,
                scatter_dims_to_operand_dims: attrs.ints("scatter_dims_to_operand_dims")?,
                index_vector_dim: attrs.int("index_vector_dim")?,
            },
            comp: comp_ref(fix, FixSlot::Scatter, attrs.req("to_apply")?),
        },
        "convolution" => Op::Convolution(parse_conv_dims(attrs)?),
        "reverse" => Op::Reverse { dims: attrs.ints("dimensions")? },
        "reduce-window" => Op::ReduceWindow {
            window: parse_window_attr(attrs.req("window")?)?,
            comp: comp_ref(fix, FixSlot::ReduceWindow, attrs.req("to_apply")?),
        },
        other => bail!("unsupported HLO opcode '{other}'"),
    })
}

// ------------------------------------------------------------ module ---

fn parse_computation(
    c: &mut Cursor,
    name: &str,
    fixups: &mut Vec<Fixup>,
    comp_idx: usize,
) -> Result<Computation> {
    let mut comp = Computation {
        name: name.to_string(),
        instrs: Vec::new(),
        root: usize::MAX,
        n_params: 0,
    };
    let mut index: HashMap<String, usize> = HashMap::new();
    loop {
        c.skip_ws(true)?;
        if c.try_eat("}") {
            break;
        }
        let is_root = c.try_eat("ROOT ");
        c.skip_ws(false)?;
        let iname = c.ident()?;
        c.skip_ws(false)?;
        c.eat("=")?;
        let shape = parse_shape(c)?;
        c.skip_ws(false)?;
        let opcode = c.ident()?;
        c.eat("(")?;
        let mut operands = Vec::new();
        let mut literal = None;
        let mut param_num = None;
        if opcode == "constant" {
            let (ty, _) = shape.array()?;
            literal = Some(parse_literal(c, ty, shape.numel())?);
            c.skip_ws(true)?;
            c.eat(")")?;
        } else if opcode == "parameter" {
            let tok = c.scan_until(b")").trim();
            let n = tok.parse::<usize>().with_context(|| format!("bad parameter '{tok}'"))?;
            param_num = Some(n);
            // parameters may appear in any textual (use) order
            comp.n_params = comp.n_params.max(n + 1);
            c.eat(")")?;
        } else {
            loop {
                c.skip_ws(true)?;
                if c.try_eat(")") {
                    break;
                }
                let oname = c.ident()?;
                let oi = *index
                    .get(oname)
                    .with_context(|| format!("{iname}: operand '{oname}' not yet defined"))?;
                operands.push(oi);
                c.skip_ws(true)?;
                c.try_eat(",");
            }
        }
        let attrs = parse_attrs(c)?;
        let mut fix = Vec::new();
        let op = build_op(opcode, &shape, &attrs, literal, param_num, &mut fix)
            .with_context(|| format!("instruction '{iname}'"))?;
        let ii = comp.instrs.len();
        for (slot, target) in fix {
            fixups.push(Fixup { comp: comp_idx, instr: ii, slot, target });
        }
        index.insert(iname.to_string(), ii);
        comp.instrs.push(Instr { name: iname.to_string(), shape, op, operands });
        if is_root {
            comp.root = ii;
        }
    }
    ensure!(comp.root != usize::MAX, "computation '{name}' has no ROOT");
    Ok(comp)
}

pub fn parse_module(text: &str) -> Result<HloModule> {
    let mut c = Cursor::new(text);
    c.skip_ws(true)?;
    c.eat("HloModule")?;
    c.skip_ws(false)?;
    let mod_name = c.ident()?.to_string();
    // skip the rest of the header line (entry_computation_layout, …)
    match c.s[c.i..].find('\n') {
        Some(j) => c.i += j + 1,
        None => c.i = c.s.len(),
    }

    let mut comps: Vec<Computation> = Vec::new();
    let mut fixups: Vec<Fixup> = Vec::new();
    let mut entry = None;
    loop {
        c.skip_ws(true)?;
        if c.eof() {
            break;
        }
        let is_entry = c.try_eat("ENTRY ");
        c.skip_ws(false)?;
        let cname = c.ident()?.to_string();
        c.skip_ws(false)?;
        c.eat("{")?;
        let comp = parse_computation(&mut c, &cname, &mut fixups, comps.len())
            .with_context(|| format!("computation '{cname}'"))?;
        if is_entry {
            entry = Some(comps.len());
        }
        comps.push(comp);
    }
    let entry = entry.context("module has no ENTRY computation")?;

    // resolve computation references
    let by_name: HashMap<String, usize> =
        comps.iter().enumerate().map(|(i, cm)| (cm.name.clone(), i)).collect();
    for f in fixups {
        let target = *by_name
            .get(&f.target)
            .with_context(|| format!("unknown computation '{}'", f.target))?;
        let op = &mut comps[f.comp].instrs[f.instr].op;
        match (&mut *op, f.slot) {
            (Op::Call { comp }, FixSlot::Call) => *comp = target,
            (Op::While { cond, .. }, FixSlot::WhileCond) => *cond = target,
            (Op::While { body, .. }, FixSlot::WhileBody) => *body = target,
            (Op::Reduce { comp, .. }, FixSlot::Reduce) => *comp = target,
            (Op::Scatter { comp, .. }, FixSlot::Scatter) => *comp = target,
            (Op::ReduceWindow { comp, .. }, FixSlot::ReduceWindow) => *comp = target,
            _ => bail!("fixup slot mismatch for '{}'", f.target),
        }
    }
    Ok(HloModule { name: mod_name, comps, entry })
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = "HloModule test, entry_computation_layout={(f32[2]{0})->f32[2]{0}}

region_0.1 {
  Arg_0.2 = f32[] parameter(0)
  Arg_1.3 = f32[] parameter(1)
  ROOT add.4 = f32[] add(Arg_0.2, Arg_1.3)
}

ENTRY main.9 {
  Arg_0.1 = f32[2]{0} parameter(0)
  constant.2 = f32[] constant(0)
  ROOT reduce.3 = f32[] reduce(Arg_0.1, constant.2), dimensions={0}, to_apply=region_0.1
}
";

    #[test]
    fn parses_tiny_module() {
        let m = parse_module(TINY).unwrap();
        assert_eq!(m.name, "test");
        assert_eq!(m.comps.len(), 2);
        assert_eq!(m.entry, 1);
        let e = m.entry_computation();
        assert_eq!(e.n_params, 1);
        assert_eq!(e.instrs.len(), 3);
        match &e.instrs[2].op {
            Op::Reduce { dims, comp } => {
                assert_eq!(dims, &[0]);
                assert_eq!(*comp, 0); // resolved to region_0.1
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(e.root, 2);
    }

    #[test]
    fn parses_shapes_and_layouts() {
        let mut c = Cursor::new("f32[2,4]{1,0} ");
        let s = parse_shape(&mut c).unwrap();
        assert_eq!(s, Shape::Array { ty: ElemType::F32, dims: vec![2, 4] });
        let mut c = Cursor::new("pred[] ");
        assert_eq!(
            parse_shape(&mut c).unwrap(),
            Shape::Array { ty: ElemType::Pred, dims: vec![] }
        );
        // tuple shape with /*index=N*/ comments
        let mut c = Cursor::new("(s32[], /*index=1*/u32[4]{0}) ");
        match parse_shape(&mut c).unwrap() {
            Shape::Tuple(elems) => {
                assert_eq!(elems.len(), 2);
                assert_eq!(elems[1], Shape::Array { ty: ElemType::U32, dims: vec![4] });
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_constants_incl_special_floats() {
        let parse_const = |text: &str, ty, numel| {
            let mut c = Cursor::new(text);
            parse_literal(&mut c, ty, numel).unwrap()
        };
        assert_eq!(parse_const("3.5)", ElemType::F32, 1), Buf::F32(vec![3.5]));
        assert_eq!(
            parse_const("{13, 15, 26, 6})", ElemType::U32, 4),
            Buf::U32(vec![13, 15, 26, 6])
        );
        assert_eq!(parse_const("false)", ElemType::Pred, 1), Buf::Pred(vec![false]));
        assert_eq!(parse_const("-1e+09)", ElemType::F32, 1), Buf::F32(vec![-1e9]));
        match parse_const("-inf)", ElemType::F32, 1) {
            Buf::F32(v) => assert!(v[0].is_infinite() && v[0] < 0.0),
            other => panic!("{other:?}"),
        }
        match parse_const("nan)", ElemType::F32, 1) {
            Buf::F32(v) => assert!(v[0].is_nan()),
            other => panic!("{other:?}"),
        }
        // nested 2-D literal flattens row-major
        assert_eq!(
            parse_const("{{1, 2}, {3, 4}})", ElemType::S32, 4),
            Buf::S32(vec![1, 2, 3, 4])
        );
    }

    #[test]
    fn parses_slice_specs() {
        assert_eq!(parse_slice_spec("{[0:1]}").unwrap(), vec![(0, 1, 1)]);
        assert_eq!(
            parse_slice_spec("{[0:2], [1:8:2]}").unwrap(),
            vec![(0, 2, 1), (1, 8, 2)]
        );
    }

    #[test]
    fn parses_gather_attrs() {
        let text = "HloModule g\n\nENTRY main.1 {\n  p0 = f32[4,8]{1,0} parameter(0)\n  \
                    p1 = s32[2,1]{1,0} parameter(1)\n  ROOT g.1 = f32[2,8]{1,0} \
                    gather(p0, p1), offset_dims={1}, collapsed_slice_dims={0}, \
                    start_index_map={0}, index_vector_dim=1, slice_sizes={1,8}\n}\n";
        let m = parse_module(text).unwrap();
        match &m.entry_computation().instrs[2].op {
            Op::Gather(g) => {
                assert_eq!(g.offset_dims, vec![1]);
                assert_eq!(g.slice_sizes, vec![1, 8]);
                assert_eq!(g.index_vector_dim, 1);
                assert!(g.operand_batching_dims.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_conv_and_window_attrs() {
        let text = "HloModule c\n\nENTRY main.1 {\n  x.1 = f32[4,16,16,3]{3,2,1,0} parameter(0)\n  \
                    w.2 = f32[3,3,3,8]{3,2,1,0} parameter(1)\n  ROOT c.3 = f32[4,8,8,8]{3,2,1,0} \
                    convolution(x.1, w.2), window={size=3x3 stride=2x2 pad=1_1x0_1 lhs_dilate=2x1}, \
                    dim_labels=b01f_01io->b01f, feature_group_count=1, batch_group_count=2\n}\n";
        let m = parse_module(text).unwrap();
        match &m.entry_computation().instrs[2].op {
            Op::Convolution(d) => {
                assert_eq!(
                    d.window[0],
                    WindowDim {
                        size: 3,
                        stride: 2,
                        pad_lo: 1,
                        pad_hi: 1,
                        base_dilation: 2,
                        window_dilation: 1
                    }
                );
                assert_eq!((d.window[1].pad_lo, d.window[1].pad_hi), (0, 1));
                assert_eq!((d.lhs_batch, d.lhs_feature, d.lhs_spatial.clone()), (0, 3, vec![1, 2]));
                assert_eq!((d.rhs_input, d.rhs_output, d.rhs_spatial.clone()), (2, 3, vec![0, 1]));
                assert_eq!((d.out_batch, d.out_feature), (0, 3));
                assert_eq!((d.feature_groups, d.batch_groups), (1, 2));
            }
            other => panic!("{other:?}"),
        }
        // defaults: omitted stride/pad/dilations are 1/0/1; the weight-grad
        // lowering's transposed labels parse too
        let text = "HloModule c\n\nENTRY main.1 {\n  x.1 = f32[16,18,18,4]{3,2,1,0} parameter(0)\n  \
                    w.2 = f32[16,16,16,4]{3,2,1,0} parameter(1)\n  ROOT c.3 = f32[3,3,1,16]{3,2,1,0} \
                    convolution(x.1, w.2), window={size=16x16}, dim_labels=f01b_i01o->01bf, \
                    batch_group_count=16\n}\n";
        let m = parse_module(text).unwrap();
        match &m.entry_computation().instrs[2].op {
            Op::Convolution(d) => {
                assert_eq!(
                    d.window[0],
                    WindowDim {
                        size: 16,
                        stride: 1,
                        pad_lo: 0,
                        pad_hi: 0,
                        base_dilation: 1,
                        window_dilation: 1
                    }
                );
                assert_eq!((d.lhs_batch, d.lhs_feature, d.lhs_spatial.clone()), (3, 0, vec![1, 2]));
                assert_eq!((d.rhs_input, d.rhs_output, d.rhs_spatial.clone()), (0, 3, vec![1, 2]));
                assert_eq!((d.out_batch, d.out_feature, d.out_spatial.clone()), (2, 3, vec![0, 1]));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn window_out_size_rule() {
        let w = |size, stride, pad_lo, pad_hi, base_dilation, window_dilation| WindowDim {
            size,
            stride,
            pad_lo,
            pad_hi,
            base_dilation,
            window_dilation,
        };
        assert_eq!(w(3, 2, 1, 1, 1, 1).out_size(16), 8); // SAME stride-2
        assert_eq!(w(3, 1, 1, 1, 1, 1).out_size(16), 16); // SAME stride-1
        assert_eq!(w(2, 2, 0, 1, 1, 1).out_size(5), 3); // asymmetric pad
        assert_eq!(w(3, 1, 2, 1, 2, 1).out_size(8), 16); // lhs_dilate=2 (grad)
        assert_eq!(w(2, 1, 0, 0, 1, 2).out_size(5), 3); // window dilation
        assert_eq!(w(4, 1, 0, 0, 1, 1).out_size(3), 0); // window > input
        assert_eq!(w(1, 1, 0, 0, 1, 1).out_size(0), 0); // degenerate input
    }

    #[test]
    fn rejects_unknown_ops_and_missing_operands() {
        let bad = "HloModule b\n\nENTRY main.1 {\n  ROOT x.1 = f32[] frobnicate()\n}\n";
        let err = format!("{:#}", parse_module(bad).unwrap_err());
        assert!(err.contains("frobnicate"), "{err}");
        let fwd = "HloModule b\n\nENTRY main.1 {\n  ROOT x.1 = f32[] add(y.2, y.2)\n}\n";
        assert!(parse_module(fwd).is_err());
    }

    #[test]
    fn out_of_order_parameters_count() {
        let text = "HloModule p\n\nENTRY main.1 {\n  b.1 = f32[] parameter(1)\n  \
                    a.2 = f32[] parameter(0)\n  ROOT s.3 = f32[] add(b.1, a.2)\n}\n";
        let m = parse_module(text).unwrap();
        assert_eq!(m.entry_computation().n_params, 2);
    }
}
