//! The HLO evaluator: walks a computation's instructions in SSA order,
//! recursing into sub-computations for `call` / `while` / `reduce` /
//! `scatter` regions.
//!
//! Determinism: evaluation is single-threaded and every loop (including
//! reduction folds) visits elements in ascending row-major order, so a
//! (module, args) pair always produces bit-identical results — across
//! runs, machines, and whatever thread count the surrounding
//! coordinator uses. jax's threefry PRNG lowers to plain integer HLO
//! (`while` loops over u32 lanes), so even in-graph randomness is exact
//! replay — no `rng-bit-generator` substitute is needed (DESIGN.md §4).
//!
//! This walker is the *reference* engine: the production path is the
//! planned executor in [`crate::runtime::interp::plan`], which must
//! match it bit-for-bit (golden-tested on the fixture). Keep the two in
//! lockstep when touching op semantics.

use anyhow::{ensure, Context, Result};

use crate::runtime::interp::ops;
use crate::runtime::interp::parser::{HloModule, Instr, Op, ScatterDims, WindowDim};
use crate::runtime::interp::value::{ArrayValue, Buf, Shape, Value};

/// Operand `k` of `ins`, which must be an array.
fn operand<'e>(env: &'e [Value], ins: &Instr, k: usize) -> Result<&'e ArrayValue> {
    env[ins.operands[k]].array()
}

pub struct Interp<'m> {
    m: &'m HloModule,
}

impl<'m> Interp<'m> {
    pub fn new(m: &'m HloModule) -> Interp<'m> {
        Interp { m }
    }

    /// Run the ENTRY computation on `args` (one value per parameter).
    pub fn run_entry(&self, args: &[Value]) -> Result<Value> {
        self.run(self.m.entry, args)
    }

    fn run(&self, comp_idx: usize, args: &[Value]) -> Result<Value> {
        let comp = &self.m.comps[comp_idx];
        ensure!(
            args.len() == comp.n_params,
            "computation '{}' takes {} parameters, got {}",
            comp.name,
            comp.n_params,
            args.len()
        );
        let mut env: Vec<Value> = Vec::with_capacity(comp.instrs.len());
        for ins in &comp.instrs {
            let v = self
                .eval_instr(ins, &env, args)
                .with_context(|| format!("evaluating {}::{}", comp.name, ins.name))?;
            env.push(v);
        }
        Ok(env.swap_remove(comp.root))
    }

    fn eval_instr(&self, ins: &Instr, env: &[Value], args: &[Value]) -> Result<Value> {
        let arr = |k: usize| operand(env, ins, k);
        Ok(match &ins.op {
            Op::Parameter(i) => args[*i].clone(),
            Op::Constant(c) => Value::Array(c.clone()),
            Op::Tuple => Value::Tuple(ins.operands.iter().map(|&j| env[j].clone()).collect()),
            Op::GetTupleElement(i) => {
                let t = env[ins.operands[0]].tuple()?;
                ensure!(*i < t.len(), "tuple index {i} out of range");
                t[*i].clone()
            }
            Op::Call { comp: target } => {
                let cargs: Vec<Value> = ins.operands.iter().map(|&j| env[j].clone()).collect();
                self.run(*target, &cargs)?
            }
            Op::While { cond, body } => {
                let mut state = env[ins.operands[0]].clone();
                loop {
                    let p = self.run(*cond, std::slice::from_ref(&state))?;
                    if !p.pred_scalar()? {
                        break;
                    }
                    state = self.run(*body, std::slice::from_ref(&state))?;
                }
                state
            }
            Op::Iota { dim } => {
                let (ty, dims) = ins.shape.array()?;
                Value::Array(ops::iota(ty, dims, *dim)?)
            }
            Op::Broadcast { dims } => {
                let (_, out_dims) = ins.shape.array()?;
                Value::Array(ops::broadcast(arr(0)?, out_dims, dims)?)
            }
            Op::Reshape => {
                let (_, out_dims) = ins.shape.array()?;
                let a = arr(0)?;
                ensure!(
                    a.numel() == out_dims.iter().product::<usize>(),
                    "reshape element count mismatch"
                );
                Value::Array(ArrayValue { dims: out_dims.to_vec(), buf: a.buf.clone() })
            }
            Op::Transpose { perm } => Value::Array(ops::transpose(arr(0)?, perm)?),
            Op::Slice { spec } => Value::Array(ops::slice(arr(0)?, spec)?),
            Op::Concatenate { dim } => {
                let parts: Vec<&ArrayValue> = ins
                    .operands
                    .iter()
                    .map(|&j| env[j].array())
                    .collect::<Result<_>>()?;
                Value::Array(ops::concatenate(&parts, *dim)?)
            }
            Op::Select => Value::Array(ops::select(arr(0)?, arr(1)?, arr(2)?)?),
            Op::Compare { dir } => Value::Array(ops::compare(*dir, arr(0)?, arr(1)?)?),
            Op::Convert => {
                let (ty, _) = ins.shape.array()?;
                Value::Array(ops::convert(arr(0)?, ty)?)
            }
            Op::BitcastConvert => {
                let (ty, _) = ins.shape.array()?;
                Value::Array(ops::bitcast_convert(arr(0)?, ty)?)
            }
            Op::Unary(u) => Value::Array(ops::unary(*u, arr(0)?)?),
            Op::Binary(b) => Value::Array(ops::binary(*b, arr(0)?, arr(1)?)?),
            Op::Dot(nums) => Value::Array(ops::dot(arr(0)?, arr(1)?, nums)?),
            Op::Gather(g) => {
                let (_, out_dims) = ins.shape.array()?;
                Value::Array(ops::gather(arr(0)?, arr(1)?, g, out_dims)?)
            }
            Op::Reduce { dims, comp: target } => self.reduce(ins, env, dims, *target)?,
            Op::Scatter { dims, comp: target } => {
                ensure!(ins.operands.len() == 3, "variadic scatter unsupported");
                self.scatter(arr(0)?, arr(1)?, arr(2)?, dims, *target)?
            }
            Op::Convolution(d) => Value::Array(ops::conv(arr(0)?, arr(1)?, d, 1)?),
            Op::Reverse { dims } => Value::Array(ops::reverse(arr(0)?, dims)?),
            Op::ReduceWindow { window, comp: target } => {
                ensure!(ins.operands.len() == 2, "variadic reduce-window unsupported");
                self.reduce_window(arr(0)?, arr(1)?, window, *target)?
            }
        })
    }

    /// (Variadic) reduce: operands are N inputs followed by N scalar
    /// inits; the region folds `(acc..., element...)` pairs. The index
    /// geometry lives in [`ops::ReduceGeom`], shared with the planned
    /// executor's fused/generic paths.
    fn reduce(&self, ins: &Instr, env: &[Value], dims: &[usize], target: usize) -> Result<Value> {
        let nops = ins.operands.len();
        ensure!(nops >= 2 && nops % 2 == 0, "reduce needs N inputs + N inits");
        let nin = nops / 2;
        let inputs: Vec<&ArrayValue> = ins.operands[..nin]
            .iter()
            .map(|&j| env[j].array())
            .collect::<Result<_>>()?;
        let inits: Vec<&ArrayValue> = ins.operands[nin..]
            .iter()
            .map(|&j| env[j].array())
            .collect::<Result<_>>()?;
        let x0 = inputs[0];
        for x in &inputs {
            ensure!(x.dims == x0.dims, "reduce input shape mismatch");
        }
        let g = ops::ReduceGeom::new(&x0.dims, dims);

        let mut outs: Vec<Buf> = inits.iter().map(|a| Buf::with_capacity(a.ty(), g.n)).collect();
        let (mut oi, mut ri) = g.scratch();
        for f in 0..g.n {
            let base = g.cell_base(f, &mut oi);
            let mut accs: Vec<Value> = inits.iter().map(|a| Value::Array(a.scalar_at(0))).collect();
            for rf in 0..g.rn {
                let xi = g.elem_index(base, rf, &mut ri);
                let mut cargs = accs;
                for x in &inputs {
                    cargs.push(Value::Array(x.scalar_at(xi)));
                }
                let res = self.run(target, &cargs)?;
                accs = match res {
                    Value::Tuple(vs) => vs,
                    v => vec![v],
                };
                ensure!(accs.len() == nin, "reduce region arity mismatch");
            }
            for (o, acc) in outs.iter_mut().zip(&accs) {
                o.push_from(&acc.array()?.buf, 0);
            }
        }
        let mut results: Vec<Value> = outs
            .into_iter()
            .map(|buf| ArrayValue::new(g.out_dims.clone(), buf).map(Value::Array))
            .collect::<Result<_>>()?;
        if matches!(ins.shape, Shape::Tuple(_)) {
            Ok(Value::Tuple(results))
        } else {
            ensure!(results.len() == 1, "reduce arity/shape mismatch");
            Ok(results.swap_remove(0))
        }
    }

    /// `reduce-window`: per output cell, fold the region over in-bounds
    /// window taps in ascending row-major order; taps that land in
    /// padding or base-dilation gaps are skipped entirely (exactly
    /// "padding is init-valued" for any fold with identity init). The
    /// index geometry lives in [`ops::WindowGeom`], shared with the
    /// planned executor's fused/generic paths.
    fn reduce_window(
        &self,
        x: &ArrayValue,
        init: &ArrayValue,
        window: &[WindowDim],
        target: usize,
    ) -> Result<Value> {
        ensure!(init.dims.is_empty(), "reduce-window init must be scalar");
        let g = ops::WindowGeom::new(&x.dims, window)?;
        let (mut oi, mut wi) = g.scratch();
        let mut out = Buf::with_capacity(init.ty(), g.n);
        for f in 0..g.n {
            g.cell_coords(f, &mut oi);
            let mut acc = Value::Array(init.scalar_at(0));
            for wf in 0..g.wn {
                if let Some(xi) = g.tap_index(&oi, wf, &mut wi) {
                    let val = Value::Array(x.scalar_at(xi));
                    acc = self.run(target, &[acc, val])?;
                }
            }
            out.push_from(&acc.array()?.buf, 0);
        }
        Ok(Value::Array(ArrayValue::new(g.out_dims.clone(), out)?))
    }

    /// StableHLO scatter (single input), including the batching dims
    /// jax emits for vmapped one-hot updates. Updates whose full index
    /// falls out of bounds are dropped, matching XLA. The index
    /// geometry lives in [`ops::scatter_walk`], shared with the
    /// planned executor's fused/generic paths.
    fn scatter(
        &self,
        operand: &ArrayValue,
        indices: &ArrayValue,
        updates: &ArrayValue,
        s: &ScatterDims,
        target: usize,
    ) -> Result<Value> {
        let mut out = (*operand.buf).clone();
        let ty = out.ty();
        ops::scatter_walk(&operand.dims, indices, updates, s, |pi, f| {
            let cur = {
                let mut b = Buf::with_capacity(ty, 1);
                b.push_from(&out, pi);
                Value::Array(ArrayValue::new(vec![], b)?)
            };
            let upd = Value::Array(updates.scalar_at(f));
            let res = self.run(target, &[cur, upd])?;
            out.set_from(pi, &res.array()?.buf, 0);
            Ok(())
        })?;
        Ok(Value::Array(ArrayValue::new(operand.dims.clone(), out)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::interp::parser::parse_module;
    use crate::runtime::interp::value::ElemType;

    fn run(text: &str, args: &[Value]) -> Value {
        let m = parse_module(text).unwrap();
        Interp::new(&m).run_entry(args).unwrap()
    }

    fn f32v(dims: &[usize], data: Vec<f32>) -> Value {
        Value::Array(ArrayValue::f32(dims, data).unwrap())
    }

    #[test]
    fn sum_reduce_hand_checked() {
        let text = "HloModule t\n\nregion_0.1 {\n  a.1 = f32[] parameter(0)\n  \
                    b.2 = f32[] parameter(1)\n  ROOT add.3 = f32[] add(a.1, b.2)\n}\n\n\
                    ENTRY main.1 {\n  x.1 = f32[2,3]{1,0} parameter(0)\n  \
                    c.2 = f32[] constant(0)\n  ROOT r.3 = f32[2]{0} reduce(x.1, c.2), \
                    dimensions={1}, to_apply=region_0.1\n}\n";
        let out = run(text, &[f32v(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])]);
        assert_eq!(out.array().unwrap().as_f32().unwrap(), &[6.0, 15.0]);
    }

    #[test]
    fn variadic_argmax_reduce() {
        // jax's argmax lowering: reduce over (value, index) pairs
        let text = "HloModule t\n\nregion_0.1 {\n  av.1 = f32[] parameter(0)\n  \
                    ai.2 = s32[] parameter(1)\n  bv.3 = f32[] parameter(2)\n  \
                    bi.4 = s32[] parameter(3)\n  ge.5 = pred[] compare(av.1, bv.3), \
                    direction=GE\n  mv.6 = f32[] select(ge.5, av.1, bv.3)\n  \
                    mi.7 = s32[] select(ge.5, ai.2, bi.4)\n  \
                    ROOT t.8 = (f32[], s32[]) tuple(mv.6, mi.7)\n}\n\n\
                    ENTRY main.1 {\n  x.1 = f32[4]{0} parameter(0)\n  \
                    i.2 = s32[4]{0} iota(), iota_dimension=0\n  \
                    ninf.3 = f32[] constant(-inf)\n  z.4 = s32[] constant(0)\n  \
                    ROOT r.5 = (f32[], s32[]) reduce(x.1, i.2, ninf.3, z.4), \
                    dimensions={0}, to_apply=region_0.1\n}\n";
        let out = run(text, &[f32v(&[4], vec![1.0, 9.0, 3.0, 9.0])]);
        let parts = out.tuple().unwrap();
        assert_eq!(parts[0].array().unwrap().as_f32().unwrap(), &[9.0]);
        // first max wins under GE folding in visit order
        match &*parts[1].array().unwrap().buf {
            Buf::S32(v) => assert_eq!(v.as_slice(), &[1]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn while_loop_counts() {
        // while (i < 5) i += 1, acc *= 2 — checks tuple state threading
        let text = "HloModule t\n\ncond.1 {\n  s.1 = (s32[], s32[]) parameter(0)\n  \
                    i.2 = s32[] get-tuple-element(s.1), index=0\n  \
                    five.3 = s32[] constant(5)\n  ROOT lt.4 = pred[] compare(i.2, five.3), \
                    direction=LT\n}\n\nbody.1 {\n  s.1 = (s32[], s32[]) parameter(0)\n  \
                    i.2 = s32[] get-tuple-element(s.1), index=0\n  \
                    a.3 = s32[] get-tuple-element(s.1), index=1\n  \
                    one.4 = s32[] constant(1)\n  two.5 = s32[] constant(2)\n  \
                    i2.6 = s32[] add(i.2, one.4)\n  a2.7 = s32[] multiply(a.3, two.5)\n  \
                    ROOT t.8 = (s32[], s32[]) tuple(i2.6, a2.7)\n}\n\n\
                    ENTRY main.1 {\n  z.1 = s32[] constant(0)\n  one.2 = s32[] constant(1)\n  \
                    st.3 = (s32[], s32[]) tuple(z.1, one.2)\n  \
                    ROOT w.4 = (s32[], s32[]) while(st.3), condition=cond.1, body=body.1\n}\n";
        let out = run(text, &[]);
        let parts = out.tuple().unwrap();
        match (&*parts[0].array().unwrap().buf, &*parts[1].array().unwrap().buf) {
            (Buf::S32(i), Buf::S32(a)) => {
                assert_eq!(i.as_slice(), &[5]);
                assert_eq!(a.as_slice(), &[32]); // 2^5
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn scatter_add_accumulates_duplicates() {
        // embedding-grad pattern: add updates into rows, duplicate index
        let text = "HloModule t\n\nadd_region.1 {\n  a.1 = f32[] parameter(0)\n  \
                    b.2 = f32[] parameter(1)\n  ROOT add.3 = f32[] add(a.1, b.2)\n}\n\n\
                    ENTRY main.1 {\n  op.1 = f32[3,2]{1,0} parameter(0)\n  \
                    idx.2 = s32[2,1]{1,0} parameter(1)\n  \
                    up.3 = f32[2,2]{1,0} parameter(2)\n  \
                    ROOT sc.4 = f32[3,2]{1,0} scatter(op.1, idx.2, up.3), \
                    update_window_dims={1}, inserted_window_dims={0}, \
                    scatter_dims_to_operand_dims={0}, index_vector_dim=1, \
                    to_apply=add_region.1\n}\n";
        let operand = f32v(&[3, 2], vec![0.0; 6]);
        let idx = Value::Array(ArrayValue::i32(&[2, 1], vec![1, 1]).unwrap());
        let upd = f32v(&[2, 2], vec![1.0, 2.0, 10.0, 20.0]);
        let out = run(text, &[operand, idx, upd]);
        assert_eq!(
            out.array().unwrap().as_f32().unwrap(),
            &[0.0, 0.0, 11.0, 22.0, 0.0, 0.0]
        );
    }

    #[test]
    fn scatter_drops_out_of_bounds() {
        let text = "HloModule t\n\nov.1 {\n  a.1 = f32[] parameter(0)\n  \
                    b.2 = f32[] parameter(1)\n  ROOT r.3 = f32[] add(a.1, b.2)\n}\n\n\
                    ENTRY main.1 {\n  op.1 = f32[2]{0} parameter(0)\n  \
                    idx.2 = s32[2,1]{1,0} parameter(1)\n  up.3 = f32[2]{0} parameter(2)\n  \
                    ROOT sc.4 = f32[2]{0} scatter(op.1, idx.2, up.3), \
                    update_window_dims={}, inserted_window_dims={0}, \
                    scatter_dims_to_operand_dims={0}, index_vector_dim=1, \
                    to_apply=ov.1\n}\n";
        let operand = f32v(&[2], vec![1.0, 1.0]);
        let idx = Value::Array(ArrayValue::i32(&[2, 1], vec![0, 7]).unwrap());
        let upd = f32v(&[2], vec![5.0, 9.0]);
        let out = run(text, &[operand, idx, upd]);
        // index 7 is out of bounds: dropped, not clamped
        assert_eq!(out.array().unwrap().as_f32().unwrap(), &[6.0, 1.0]);
    }

    #[test]
    fn conv_and_reverse_through_hlo_text() {
        // 1-D SAME conv (dim_labels b0f_0io->b0f) over a reversed input:
        // end-to-end through the parser, hand-checked
        let text = "HloModule t\n\nENTRY main.1 {\n  x.1 = f32[1,4,1]{2,1,0} parameter(0)\n  \
                    r.2 = f32[1,4,1]{2,1,0} reverse(x.1), dimensions={1}\n  \
                    w.3 = f32[3,1,1]{2,1,0} parameter(1)\n  \
                    ROOT c.4 = f32[1,4,1]{2,1,0} convolution(r.2, w.3), \
                    window={size=3 pad=1_1}, dim_labels=b0f_0io->b0f\n}\n";
        let x = f32v(&[1, 4, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let w = f32v(&[3, 1, 1], vec![1.0, 1.0, 1.0]);
        let out = run(text, &[x, w]);
        // reversed input is [4,3,2,1]; SAME box filter sums neighbours
        assert_eq!(out.array().unwrap().as_f32().unwrap(), &[7.0, 9.0, 6.0, 3.0]);
    }

    #[test]
    fn reduce_window_runs_arbitrary_regions() {
        // a 4-instruction region (sum of squares) the fused matcher can
        // never claim: the oracle must fold it via region invocation
        let text = "HloModule t\n\nsq.1 {\n  a.1 = f32[] parameter(0)\n  \
                    b.2 = f32[] parameter(1)\n  m.3 = f32[] multiply(b.2, b.2)\n  \
                    ROOT r.4 = f32[] add(a.1, m.3)\n}\n\n\
                    ENTRY main.1 {\n  x.1 = f32[3]{0} parameter(0)\n  \
                    z.2 = f32[] constant(0)\n  \
                    ROOT rw.3 = f32[2]{0} reduce-window(x.1, z.2), \
                    window={size=2}, to_apply=sq.1\n}\n";
        let out = run(text, &[f32v(&[3], vec![1.0, 2.0, 3.0])]);
        assert_eq!(out.array().unwrap().as_f32().unwrap(), &[5.0, 13.0]);
    }

    #[test]
    fn call_and_nested_computations() {
        let text = "HloModule t\n\ndouble.1 {\n  x.1 = f32[2]{0} parameter(0)\n  \
                    ROOT d.2 = f32[2]{0} add(x.1, x.1)\n}\n\n\
                    ENTRY main.1 {\n  p.1 = f32[2]{0} parameter(0)\n  \
                    c.2 = f32[2]{0} call(p.1), to_apply=double.1\n  \
                    ROOT c2.3 = f32[2]{0} call(c.2), to_apply=double.1\n}\n";
        let out = run(text, &[f32v(&[2], vec![1.5, -2.0])]);
        assert_eq!(out.array().unwrap().as_f32().unwrap(), &[6.0, -8.0]);
    }

    #[test]
    fn softmax_cross_entropy_numerics() {
        // exp/log/divide/reduce together: softmax of a 1x3 row then log
        let text = "HloModule t\n\nsum.1 {\n  a.1 = f32[] parameter(0)\n  \
                    b.2 = f32[] parameter(1)\n  ROOT add.3 = f32[] add(a.1, b.2)\n}\n\n\
                    ENTRY main.1 {\n  x.1 = f32[3]{0} parameter(0)\n  \
                    e.2 = f32[3]{0} exponential(x.1)\n  z.3 = f32[] constant(0)\n  \
                    s.4 = f32[] reduce(e.2, z.3), dimensions={0}, to_apply=sum.1\n  \
                    sb.5 = f32[3]{0} broadcast(s.4), dimensions={}\n  \
                    ROOT p.6 = f32[3]{0} divide(e.2, sb.5)\n}\n";
        let out = run(text, &[f32v(&[3], vec![0.0, 1.0, 2.0])]);
        let p = out.array().unwrap().as_f32().unwrap().to_vec();
        let want = {
            let e: Vec<f32> = [0.0f32, 1.0, 2.0].iter().map(|x| x.exp()).collect();
            let s: f32 = e.iter().sum();
            e.iter().map(|x| x / s).collect::<Vec<f32>>()
        };
        for (a, b) in p.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "{p:?} vs {want:?}");
        }
        let total: f32 = p.iter().sum();
        assert!((total - 1.0).abs() < 1e-6);
    }

    #[test]
    fn deterministic_across_runs() {
        let text = "HloModule t\n\nENTRY main.1 {\n  x.1 = f32[4]{0} parameter(0)\n  \
                    e.2 = f32[4]{0} exponential(x.1)\n  s.3 = f32[4]{0} sine(e.2)\n  \
                    ROOT m.4 = f32[4]{0} multiply(s.3, e.2)\n}\n";
        let m = parse_module(text).unwrap();
        let args = vec![f32v(&[4], vec![0.1, 0.7, -1.3, 2.9])];
        let a = Interp::new(&m).run_entry(&args).unwrap();
        let b = Interp::new(&m).run_entry(&args).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn iota_compare_select_tril_pattern() {
        // the causal-mask construction the LM uses (tril via iota GE)
        let text = "HloModule t\n\nENTRY main.1 {\n  i0.1 = s32[3]{0} iota(), \
                    iota_dimension=0\n  r.2 = s32[3,3]{1,0} broadcast(i0.1), \
                    dimensions={0}\n  i1.3 = s32[3]{0} iota(), iota_dimension=0\n  \
                    c.4 = s32[3,3]{1,0} broadcast(i1.3), dimensions={1}\n  \
                    ROOT ge.5 = pred[3,3]{1,0} compare(r.2, c.4), direction=GE\n}\n";
        let out = run(text, &[]);
        assert_eq!(
            out.array().unwrap().as_pred().unwrap(),
            &[true, false, false, true, true, false, true, true, true]
        );
    }

    #[test]
    fn convert_between_all_artifact_types() {
        let text = "HloModule t\n\nENTRY main.1 {\n  x.1 = s32[2]{0} parameter(0)\n  \
                    ROOT f.2 = f32[2]{0} convert(x.1)\n}\n";
        let out = run(
            text,
            &[Value::Array(ArrayValue::i32(&[2], vec![-3, 7]).unwrap())],
        );
        assert_eq!(out.array().unwrap().as_f32().unwrap(), &[-3.0, 7.0]);
        let r = ops::convert(
            &ArrayValue::new(vec![2], Buf::Pred(vec![true, false])).unwrap(),
            ElemType::F32,
        )
        .unwrap();
        assert_eq!(r.as_f32().unwrap(), &[1.0, 0.0]);
    }
}
