//! Plan-and-execute engine: lower a parsed [`HloModule`] once into a
//! typed instruction plan with last-use liveness, then execute it many
//! times on reference-counted, copy-on-write buffers.
//!
//! What the plan buys over the tree-walking reference evaluator
//! ([`crate::runtime::interp::eval`]):
//!
//! * **Liveness / in-place ops.** Each register is dropped at its last
//!   use, and elementwise steps whose operand dies there mutate that
//!   buffer in place via [`ArrayValue::buf_mut`] (`Arc::make_mut`):
//!   uniquely-owned buffers are reused, shared ones are cloned first —
//!   copy-on-write, so a live value is never aliased. `while` state,
//!   tuple plumbing and `call` arguments *move* instead of cloning.
//! * **Fused regions.** `reduce`/`scatter` regions that are a single
//!   scalar binary op (the overwhelmingly common case: add/max/min/and)
//!   fold inline instead of invoking the sub-computation per element.
//! * **Blocked dot.** The general dot packs both operands into
//!   contiguous `[batch][free][k]` panels, transposes the rhs panel
//!   into `LANE_BLOCK`-wide register tiles (the `dot8` pattern from
//!   `quant/assign.rs`), and contracts eight output columns per lhs row
//!   at once with 4-way partial sums; large outputs shard across
//!   `thread::scope` workers.
//! * **Loop fusion** ([`crate::runtime::interp::fuse`]). Counted
//!   `while` loops run as a trip-counted superinstruction on unpacked
//!   state registers (no per-iteration condition or tuple
//!   pack/unpack), and jax's threefry-2x32 PRNG round bodies execute
//!   as the native [`ops::threefry2x32`] kernel — one unrolled pass
//!   over the flat u32 lanes instead of ~55 tiny-array ops.
//! * **Elementwise chains** ([`crate::runtime::interp::fuse`]). Runs
//!   of single-use elementwise steps (plus folded broadcast-of-scalar
//!   splats) collapse into one superinstruction per chain: a compiled
//!   per-element op tape evaluated in a single pass over the output
//!   buffer — no intermediate buffers, one dispatch per chain instead
//!   of one per step, in place on a dying operand when liveness allows.
//! * **Intra-op sharding.** Fused reduces, large elementwise ops and
//!   threefry lanes shard across `thread::scope` workers above a size
//!   threshold, merged in ascending-shard order like the packed dot.
//!
//! **Determinism contract (DESIGN.md §4).** Every kernel visits the
//! same elements in the same order as the reference evaluator and uses
//! the identical per-element scalar helpers (integer superinstructions
//! regroup only exact wrapping arithmetic), so planned execution is
//! bit-identical to the tree walk — and, because each output element is
//! computed independently by the same scalar code regardless of
//! sharding, bit-identical across thread counts (the same contract as
//! `quant::assign`). Golden-tested on the `lm_tiny` fixture in
//! `tests/interp_plan.rs` and `tests/interp_fuse.rs`.

use anyhow::{bail, ensure, Context, Result};

use crate::quant::assign;
use crate::runtime::interp::fuse::{self, CountedLoop};
use crate::runtime::interp::ops::{self, f32_bin, pred_bin, s32_bin, u32_bin};
use crate::runtime::interp::parser::{
    BinaryOp, Computation, DotDims, HloModule, Instr, Op, ScatterDims, UnaryOp, WindowDim,
};
use crate::runtime::interp::stats::Stats;
use crate::runtime::interp::value::{strides_of, ArrayValue, Buf, ElemType, Shape, Value};
use crate::runtime::interp::verify;

/// Output-element count above which the packed dot shards its output
/// rows across worker threads (below it, spawn overhead dominates).
const DOT_PAR_MIN: usize = 4096;

/// Fused lowering of an instruction, decided at plan time.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Fused {
    /// Run the sub-computation per element / iteration (general
    /// fallback).
    None,
    /// Reduce/scatter region is a single scalar binary op; `acc_first`
    /// says whether it computes `op(acc, elem)` (else `op(elem, acc)`).
    Bin { op: BinaryOp, acc_first: bool },
    /// Counted `while`: run the body plan `bound - start` times on
    /// unpacked state registers, no per-iteration condition or tuple
    /// pack/unpack (see [`crate::runtime::interp::fuse`]).
    Counted(Box<CountedLoop>),
    /// `call` to a threefry-2x32 round body: execute the native
    /// [`ops::threefry2x32`] kernel over the flat u32 lanes.
    Threefry,
    /// Root of an elementwise chain: run the compiled per-element op
    /// tape in one pass over the output buffer
    /// ([`crate::runtime::interp::fuse::ChainSpec`]).
    Chain(Box<fuse::ChainSpec>),
    /// Member of the chain rooted at `root`: never executed, its
    /// register is never written (reading one fails fast).
    ChainInterior { root: usize },
}

/// Which fusion rewrites [`Plan::compile_opts`] applies. Disabling them
/// (benches, regression tests) yields the pre-fusion planned executor;
/// results are bit-identical either way.
#[derive(Debug, Clone, Copy)]
pub struct PlanOptions {
    /// Lower counted `while` loops to the trip-counted
    /// superinstruction ([`crate::runtime::interp::fuse`]).
    pub counted_loops: bool,
    /// Execute matched threefry round bodies natively.
    pub threefry: bool,
    /// Collapse single-use elementwise runs into chain
    /// superinstructions ([`crate::runtime::interp::fuse::ChainSpec`]).
    pub chains: bool,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions { counted_loops: true, threefry: true, chains: true }
    }
}

/// Plan-time fusion census (tests / diagnostics): how many
/// instructions each rewrite captured, module-wide.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FusionStats {
    /// `while` instructions lowered to counted loops.
    pub counted_loops: usize,
    /// `while` instructions left on the generic path.
    pub generic_whiles: usize,
    /// `call` sites executing the native threefry kernel.
    pub threefry_calls: usize,
    /// Reduce instructions with an inlined single-binary-op region.
    pub fused_reduces: usize,
    /// Scatter instructions with an inlined single-binary-op region.
    pub fused_scatters: usize,
    /// Reduce-window instructions with an inlined single-binary-op
    /// region (pooling layers).
    pub fused_windows: usize,
    /// Elementwise-chain superinstructions (one per chain root).
    pub fused_chains: usize,
    /// Instructions captured by chains, roots included (each chain
    /// contributes `steps.len() + 1`).
    pub chain_steps: usize,
}

/// One computation lowered for planned execution. Fields are
/// crate-visible so [`crate::runtime::interp::verify`] can audit (and
/// its tests corrupt) the schedule directly.
#[derive(Debug)]
pub(crate) struct CompPlan {
    pub(crate) name: String,
    pub(crate) instrs: Vec<Instr>,
    pub(crate) root: usize,
    pub(crate) n_params: usize,
    /// Registers whose last use is step `i` (dropped after it runs).
    pub(crate) free_after: Vec<Vec<usize>>,
    /// Per step, per operand: move the register out instead of cloning
    /// (true iff this is the operand's unique, final use).
    pub(crate) take: Vec<Vec<bool>>,
    pub(crate) fused: Vec<Fused>,
}

/// A compiled module: liveness-annotated instruction plans for every
/// computation, ready for repeated (and batch-sharded) execution.
#[derive(Debug)]
pub struct Plan {
    pub(crate) comps: Vec<CompPlan>,
    pub(crate) entry: usize,
    pub(crate) entry_params: Vec<Option<Shape>>,
    /// `QN_INTERP_STATS` op histogram, printed when the plan drops.
    pub(crate) stats: Option<Stats>,
}

impl Plan {
    /// Lower a parsed module: compute last-use liveness and move flags
    /// per computation and classify fusable regions (single-binary-op
    /// reduce/scatter, counted `while` loops, threefry round calls).
    pub fn compile(m: &HloModule) -> Plan {
        Plan::compile_opts(m, PlanOptions::default())
    }

    /// [`Plan::compile`] with explicit fusion switches. In debug builds
    /// (and under `QN_PLAN_VERIFY=1` in release) the compiled plan runs
    /// through the static verifier and a diagnostic is a panic — a
    /// planner bug must not reach execution. Callers that want the
    /// diagnostics as data (the plan cache, `qn lint-plan`) use
    /// [`Plan::compile_unverified`] and call [`verify::verify`]
    /// themselves.
    pub fn compile_opts(m: &HloModule, opts: PlanOptions) -> Plan {
        let plan = Plan::compile_unverified(m, opts);
        if verify::should_verify() {
            let diags = verify::verify(&plan);
            if !diags.is_empty() {
                panic!(
                    "plan verification failed for module '{}':\n{}",
                    m.name,
                    verify::render(&diags)
                );
            }
        }
        plan
    }

    /// Lower a module without the static-verification gate.
    pub fn compile_unverified(m: &HloModule, opts: PlanOptions) -> Plan {
        let threefry: Vec<bool> =
            m.comps.iter().map(|c| opts.threefry && fuse::match_threefry(c)).collect();
        let comps = m
            .comps
            .iter()
            .map(|c| {
                let mut fused: Vec<Fused> =
                    c.instrs.iter().map(|ins| classify(m, ins, &threefry, opts)).collect();
                if opts.chains {
                    for (root, spec) in fuse::match_chains(c) {
                        for &s in &spec.steps {
                            fused[s] = Fused::ChainInterior { root };
                        }
                        fused[root] = Fused::Chain(Box::new(spec));
                    }
                }
                // liveness must see through elision: a use at an elided
                // chain member keeps its register alive until the chain
                // root actually reads it
                let (free_after, take) = analyze(c, &fused);
                finish_chains(c, &mut fused, &free_after);
                CompPlan {
                    name: c.name.clone(),
                    instrs: c.instrs.clone(),
                    root: c.root,
                    n_params: c.n_params,
                    free_after,
                    take,
                    fused,
                }
            })
            .collect();
        let e = &m.comps[m.entry];
        let mut entry_params = vec![None; e.n_params];
        for ins in &e.instrs {
            if let Op::Parameter(i) = &ins.op {
                entry_params[*i] = Some(ins.shape.clone());
            }
        }
        Plan { comps, entry: m.entry, entry_params, stats: Stats::from_env(&m.name) }
    }

    /// How many instructions each fusion rewrite captured.
    pub fn fusion_stats(&self) -> FusionStats {
        let mut fs = FusionStats::default();
        for comp in &self.comps {
            for (ins, fused) in comp.instrs.iter().zip(&comp.fused) {
                match (&ins.op, fused) {
                    (Op::While { .. }, Fused::Counted(_)) => fs.counted_loops += 1,
                    (Op::While { .. }, _) => fs.generic_whiles += 1,
                    (Op::Call { .. }, Fused::Threefry) => fs.threefry_calls += 1,
                    (Op::Reduce { .. }, Fused::Bin { .. }) => fs.fused_reduces += 1,
                    (Op::Scatter { .. }, Fused::Bin { .. }) => fs.fused_scatters += 1,
                    (Op::ReduceWindow { .. }, Fused::Bin { .. }) => fs.fused_windows += 1,
                    (_, Fused::Chain(spec)) => {
                        fs.fused_chains += 1;
                        fs.chain_steps += spec.steps.len() + 1;
                    }
                    _ => {}
                }
            }
        }
        fs
    }

    /// Declared shape of ENTRY parameter `i` (None if the parameter
    /// never appears in the entry computation).
    pub fn entry_param_shape(&self, i: usize) -> Option<&Shape> {
        self.entry_params.get(i).and_then(|s| s.as_ref())
    }

    pub fn n_entry_params(&self) -> usize {
        self.entry_params.len()
    }

    /// Run the ENTRY computation. `threads` bounds the worker count of
    /// intra-op sharding (1 = fully serial); any value produces
    /// bit-identical results.
    pub fn run_entry(&self, args: Vec<Value>, threads: usize) -> Result<Value> {
        Executor { plan: self, threads: threads.max(1) }.run(self.entry, args)
    }
}

// ------------------------------------------------------------ analysis ---

/// Last-use liveness over one computation. A use at a step elided into
/// an elementwise chain ([`Fused::ChainInterior`]) is attributed to
/// the chain's root — that is where the executor actually reads the
/// register — so nothing is freed before the chain runs, and chain
/// interiors (whose registers are never written) are dropped from the
/// register file right after their root.
fn analyze(c: &Computation, fused: &[Fused]) -> (Vec<Vec<usize>>, Vec<Vec<bool>>) {
    let n = c.instrs.len();
    let site = |si: usize| match fused[si] {
        Fused::ChainInterior { root } => root,
        _ => si,
    };
    let mut last = vec![usize::MAX; n];
    for (si, ins) in c.instrs.iter().enumerate() {
        for &o in &ins.operands {
            // effective use sites are no longer monotone in `si` (an
            // elided member's use lands at its later root), so keep the
            // max rather than the final write
            let s = site(si);
            last[o] = if last[o] == usize::MAX { s } else { last[o].max(s) };
        }
    }
    let mut free_after: Vec<Vec<usize>> = vec![Vec::new(); n];
    for r in 0..n {
        if r == c.root {
            continue; // the root must survive to be returned
        }
        let l = if last[r] == usize::MAX { r } else { last[r] };
        free_after[l].push(r);
    }
    let take = c
        .instrs
        .iter()
        .enumerate()
        .map(|(si, ins)| {
            ins.operands
                .iter()
                .map(|&o| {
                    o != c.root
                        && last[o] == si
                        && ins.operands.iter().filter(|&&x| x == o).count() == 1
                })
                .collect()
        })
        .collect();
    (free_after, take)
}

/// Fill in the liveness-dependent fields of every chain spec: which
/// input registers die at the root (consumable by the kernel) and
/// which one, if any, the chain overwrites in place. Runs after
/// [`analyze`], which already attributed elided uses to the roots —
/// `free_after[root]` is exactly the set of registers whose last
/// effective use is the chain.
fn finish_chains(c: &Computation, fused: &mut [Fused], free_after: &[Vec<usize>]) {
    for si in 0..fused.len() {
        let Fused::Chain(spec) = &mut fused[si] else { continue };
        let Ok((oty, odims)) = c.instrs[si].shape.array() else { continue };
        for i in 0..spec.inputs.len() {
            let r = spec.inputs[i].reg();
            // a register feeding two slots (e.g. both a full lane and a
            // splat source) must not be moved out from either
            let dup = spec.inputs.iter().filter(|inp| inp.reg() == r).count() > 1;
            spec.take[i] = !dup && free_after[si].contains(&r);
        }
        spec.inplace = spec.inputs.iter().enumerate().find_map(|(i, inp)| match *inp {
            fuse::ChainInput::Full(r)
                if spec.take[i]
                    && c.instrs[r]
                        .shape
                        .array()
                        .map(|(t, d)| t == oty && d == odims)
                        .unwrap_or(false) =>
            {
                Some(i)
            }
            _ => None,
        });
    }
}

/// Recognize a region that is a single scalar binary op over its two
/// parameters: `{ p0, p1, ROOT bin(p0, p1) }` (either operand order).
fn match_bin_region(c: &Computation) -> Option<(BinaryOp, bool)> {
    if c.instrs.len() != 3 || c.n_params != 2 {
        return None;
    }
    let mut p0 = None;
    let mut p1 = None;
    for (i, ins) in c.instrs.iter().enumerate() {
        match ins.op {
            Op::Parameter(0) => p0 = Some(i),
            Op::Parameter(1) => p1 = Some(i),
            _ => {}
        }
    }
    let (p0, p1) = (p0?, p1?);
    let root = &c.instrs[c.root];
    if let Op::Binary(op) = root.op {
        if root.operands == [p0, p1] {
            return Some((op, true));
        }
        if root.operands == [p1, p0] {
            return Some((op, false));
        }
    }
    None
}

fn classify(m: &HloModule, ins: &Instr, threefry: &[bool], opts: PlanOptions) -> Fused {
    let target = match &ins.op {
        Op::Reduce { comp, .. }
            if ins.operands.len() == 2 && matches!(ins.shape, Shape::Array { .. }) =>
        {
            *comp
        }
        Op::Scatter { comp, .. } if ins.operands.len() == 3 => *comp,
        Op::ReduceWindow { comp, .. } if ins.operands.len() == 2 => *comp,
        Op::Call { comp } if threefry[*comp] => return Fused::Threefry,
        Op::While { cond, body } if opts.counted_loops => {
            return match fuse::match_counted_loop(m, *cond, *body) {
                Some(spec) => Fused::Counted(Box::new(spec)),
                None => Fused::None,
            };
        }
        _ => return Fused::None,
    };
    match match_bin_region(&m.comps[target]) {
        Some((op, acc_first)) => Fused::Bin { op, acc_first },
        None => Fused::None,
    }
}

/// Stats label of one planned instruction, plus whether it is a *leaf*
/// (does not recurse into sub-plans, so its wall-clock is self time).
pub(crate) fn op_label(ins: &Instr, fused: &Fused) -> (&'static str, bool) {
    match (&ins.op, fused) {
        // chain annotations take precedence over the per-op labels:
        // the root runs the whole tape, interiors never run at all
        (_, Fused::Chain(_)) => ("chain[elementwise]", true),
        (_, Fused::ChainInterior { .. }) => ("chain[interior]", true),
        (Op::While { .. }, Fused::Counted(_)) => ("while[counted]", false),
        (Op::While { .. }, _) => ("while[generic]", false),
        (Op::Call { .. }, Fused::Threefry) => ("call[threefry2x32]", true),
        (Op::Call { .. }, _) => ("call", false),
        (Op::Reduce { .. }, Fused::Bin { .. }) => ("reduce[fused]", true),
        (Op::Reduce { .. }, _) => ("reduce[generic]", false),
        (Op::Scatter { .. }, Fused::Bin { .. }) => ("scatter[fused]", true),
        (Op::Scatter { .. }, _) => ("scatter[generic]", false),
        (Op::ReduceWindow { .. }, Fused::Bin { .. }) => ("reduce-window[fused]", true),
        (Op::ReduceWindow { .. }, _) => ("reduce-window[generic]", false),
        (Op::Convolution(_), _) => ("conv[direct]", true),
        (Op::Reverse { .. }, _) => ("reverse", true),
        (Op::Dot(_), _) => ("dot[packed]", true),
        (Op::Parameter(_), _) => ("parameter", true),
        (Op::Constant(_), _) => ("constant", true),
        (Op::Tuple, _) => ("tuple", true),
        (Op::GetTupleElement(_), _) => ("get-tuple-element", true),
        (Op::Iota { .. }, _) => ("iota", true),
        (Op::Broadcast { .. }, _) => ("broadcast", true),
        (Op::Reshape, _) => ("reshape", true),
        (Op::Transpose { .. }, _) => ("transpose", true),
        (Op::Slice { .. }, _) => ("slice", true),
        (Op::Concatenate { .. }, _) => ("concatenate", true),
        (Op::Select, _) => ("select", true),
        (Op::Compare { .. }, _) => ("compare", true),
        (Op::Convert, _) => ("convert", true),
        (Op::BitcastConvert, _) => ("bitcast-convert", true),
        (Op::Gather(_), _) => ("gather", true),
        (Op::Unary(u), _) => (
            match u {
                UnaryOp::Negate => "negate",
                UnaryOp::Exp => "exponential",
                UnaryOp::Log => "log",
                UnaryOp::Rsqrt => "rsqrt",
                UnaryOp::Sine => "sine",
                UnaryOp::Cosine => "cosine",
                UnaryOp::RoundNearestEven => "round-nearest-even",
            },
            true,
        ),
        (Op::Binary(b), _) => (
            match b {
                BinaryOp::Add => "add",
                BinaryOp::Sub => "subtract",
                BinaryOp::Mul => "multiply",
                BinaryOp::Div => "divide",
                BinaryOp::Max => "maximum",
                BinaryOp::Min => "minimum",
                BinaryOp::Pow => "power",
                BinaryOp::And => "and",
                BinaryOp::Or => "or",
                BinaryOp::Xor => "xor",
                BinaryOp::Shl => "shift-left",
                BinaryOp::ShrLogical => "shift-right-logical",
            },
            true,
        ),
    }
}

// ------------------------------------------------------------ executor ---

struct Executor<'p> {
    plan: &'p Plan,
    threads: usize,
}

impl<'p> Executor<'p> {
    fn run(&self, ci: usize, args: Vec<Value>) -> Result<Value> {
        let comp = &self.plan.comps[ci];
        ensure!(
            args.len() == comp.n_params,
            "computation '{}' takes {} parameters, got {}",
            comp.name,
            comp.n_params,
            args.len()
        );
        let mut args: Vec<Option<Value>> = args.into_iter().map(Some).collect();
        let mut regs: Vec<Option<Value>> = (0..comp.instrs.len()).map(|_| None).collect();
        for si in 0..comp.instrs.len() {
            if matches!(comp.fused[si], Fused::ChainInterior { .. }) {
                // claimed by a chain root downstream; never executed,
                // register stays None (nothing frees at elided steps —
                // analyze() attributed every use here to the root)
                continue;
            }
            let v = self
                .exec_step(comp, si, &mut regs, &mut args)
                .with_context(|| format!("executing {}::{}", comp.name, comp.instrs[si].name))?;
            regs[si] = Some(v);
            for &r in &comp.free_after[si] {
                regs[r] = None;
            }
        }
        Ok(regs[comp.root].take().expect("root register computed"))
    }

    /// [`Executor::step`], wrapped with the optional stats collector:
    /// leaf ops record self time, recursive ops record counts only.
    fn exec_step(
        &self,
        comp: &CompPlan,
        si: usize,
        regs: &mut Vec<Option<Value>>,
        args: &mut [Option<Value>],
    ) -> Result<Value> {
        let Some(stats) = &self.plan.stats else {
            return self.step(comp, si, regs, args);
        };
        let (label, leaf) = op_label(&comp.instrs[si], &comp.fused[si]);
        if leaf {
            // opt-in profiling only (QN_INTERP_STATS), never feeds results
            #[allow(clippy::disallowed_methods)]
            let t0 = std::time::Instant::now();
            let v = self.step(comp, si, regs, args);
            stats.record(label, Some(t0.elapsed()));
            v
        } else {
            stats.record(label, None);
            self.step(comp, si, regs, args)
        }
    }

    /// Operand `k` of step `si` by value: moved out of its register
    /// when this is its unique final use, cloned (O(1), Arc) otherwise.
    fn fetch(&self, comp: &CompPlan, si: usize, k: usize, regs: &mut [Option<Value>]) -> Value {
        let o = comp.instrs[si].operands[k];
        if comp.take[si][k] {
            regs[o].take().expect("operand register computed")
        } else {
            regs[o].clone().expect("operand register computed")
        }
    }

    /// Operand `k` of step `si` by reference (must be an array).
    fn arr<'a>(
        &self,
        comp: &CompPlan,
        si: usize,
        k: usize,
        regs: &'a [Option<Value>],
    ) -> Result<&'a ArrayValue> {
        let o = comp.instrs[si].operands[k];
        regs[o].as_ref().expect("operand register computed").array()
    }

    fn step(
        &self,
        comp: &CompPlan,
        si: usize,
        regs: &mut Vec<Option<Value>>,
        args: &mut [Option<Value>],
    ) -> Result<Value> {
        let ins = &comp.instrs[si];
        if let Fused::Chain(spec) = &comp.fused[si] {
            return self.chain_exec(comp, si, spec, regs);
        }
        Ok(match &ins.op {
            Op::Parameter(i) => args
                .get_mut(*i)
                .and_then(|a| a.take())
                .with_context(|| format!("parameter {i} unavailable"))?,
            Op::Constant(c) => Value::Array(c.clone()),
            Op::Tuple => {
                let mut vs = Vec::with_capacity(ins.operands.len());
                for k in 0..ins.operands.len() {
                    vs.push(self.fetch(comp, si, k, regs));
                }
                Value::Tuple(vs)
            }
            Op::GetTupleElement(i) => {
                if comp.take[si][0] {
                    match self.fetch(comp, si, 0, regs) {
                        Value::Tuple(mut vs) => {
                            ensure!(*i < vs.len(), "tuple index {i} out of range");
                            vs.swap_remove(*i)
                        }
                        Value::Array(_) => bail!("expected tuple value, got array"),
                    }
                } else {
                    let t = regs[ins.operands[0]].as_ref().expect("operand").tuple()?;
                    ensure!(*i < t.len(), "tuple index {i} out of range");
                    t[*i].clone()
                }
            }
            Op::Call { comp: target } => {
                if matches!(comp.fused[si], Fused::Threefry) {
                    return self.threefry_call(comp, si, regs);
                }
                let mut cargs = Vec::with_capacity(ins.operands.len());
                for k in 0..ins.operands.len() {
                    cargs.push(self.fetch(comp, si, k, regs));
                }
                self.run(*target, cargs)?
            }
            Op::While { cond, body } => {
                if let Fused::Counted(spec) = &comp.fused[si] {
                    let init = self.fetch(comp, si, 0, regs);
                    return self.counted_loop(spec, init);
                }
                let mut state = self.fetch(comp, si, 0, regs);
                loop {
                    let p = self.run(*cond, vec![state.clone()])?;
                    if !p.pred_scalar()? {
                        break;
                    }
                    state = self.run(*body, vec![state])?;
                }
                state
            }
            Op::Iota { dim } => {
                let (ty, dims) = ins.shape.array()?;
                Value::Array(ops::iota(ty, dims, *dim)?)
            }
            Op::Broadcast { dims } => {
                let (_, out_dims) = ins.shape.array()?;
                Value::Array(ops::broadcast(self.arr(comp, si, 0, regs)?, out_dims, dims)?)
            }
            Op::Reshape => {
                let (_, out_dims) = ins.shape.array()?;
                let a = self.fetch(comp, si, 0, regs).into_array()?;
                ensure!(
                    a.numel() == out_dims.iter().product::<usize>(),
                    "reshape element count mismatch"
                );
                // O(1): same storage, new logical dims
                Value::Array(ArrayValue { dims: out_dims.to_vec(), buf: a.buf })
            }
            Op::Transpose { perm } => {
                Value::Array(ops::transpose(self.arr(comp, si, 0, regs)?, perm)?)
            }
            Op::Slice { spec } => Value::Array(ops::slice(self.arr(comp, si, 0, regs)?, spec)?),
            Op::Concatenate { dim } => {
                let parts: Vec<&ArrayValue> = ins
                    .operands
                    .iter()
                    .map(|&o| regs[o].as_ref().expect("operand").array())
                    .collect::<Result<_>>()?;
                Value::Array(ops::concatenate(&parts, *dim)?)
            }
            Op::Select => {
                let (t1, t2) = (comp.take[si][1], comp.take[si][2]);
                let (dst_is_true, dst_k, src_k) =
                    if t2 && !t1 { (false, 2, 1) } else { (true, 1, 2) };
                if t1 || t2 || self.arr(comp, si, 0, regs)?.numel() >= ops::ELEM_PAR_MIN {
                    // in-place when a branch dies here; for large fresh
                    // outputs, CoW-clone the kept branch then run the
                    // sharded kernel (bit-identical to the serial copy)
                    let mut dst = self.fetch(comp, si, dst_k, regs).into_array()?;
                    let p = self.arr(comp, si, 0, regs)?;
                    let src = self.arr(comp, si, src_k, regs)?;
                    ensure!(
                        p.dims == dst.dims && dst.dims == src.dims,
                        "select shape mismatch"
                    );
                    let pred = p.as_pred()?;
                    ops::select_inplace_sharded(
                        pred,
                        dst_is_true,
                        dst.buf_mut(),
                        &src.buf,
                        self.threads,
                    )?;
                    Value::Array(dst)
                } else {
                    Value::Array(ops::select(
                        self.arr(comp, si, 0, regs)?,
                        self.arr(comp, si, 1, regs)?,
                        self.arr(comp, si, 2, regs)?,
                    )?)
                }
            }
            Op::Compare { dir } => Value::Array(ops::compare(
                *dir,
                self.arr(comp, si, 0, regs)?,
                self.arr(comp, si, 1, regs)?,
            )?),
            Op::Convert => {
                let (ty, _) = ins.shape.array()?;
                let v = self.fetch(comp, si, 0, regs);
                let a = v.into_array()?;
                if a.ty() == ty {
                    Value::Array(a) // no-op convert: share storage (CoW)
                } else {
                    Value::Array(ops::convert(&a, ty)?)
                }
            }
            Op::BitcastConvert => {
                let (ty, _) = ins.shape.array()?;
                let v = self.fetch(comp, si, 0, regs);
                let a = v.into_array()?;
                if a.ty() == ty {
                    Value::Array(a)
                } else {
                    Value::Array(ops::bitcast_convert(&a, ty)?)
                }
            }
            Op::Unary(u) => {
                if comp.take[si][0] || self.arr(comp, si, 0, regs)?.numel() >= ops::ELEM_PAR_MIN
                {
                    // in-place on a dying operand, or CoW-clone + the
                    // sharded kernel for large fresh outputs
                    let mut a = self.fetch(comp, si, 0, regs).into_array()?;
                    ops::unary_inplace_sharded(*u, a.buf_mut(), self.threads)?;
                    Value::Array(a)
                } else {
                    Value::Array(ops::unary(*u, self.arr(comp, si, 0, regs)?)?)
                }
            }
            Op::Binary(b) => {
                let (t0, t1) = (comp.take[si][0], comp.take[si][1]);
                let (dst_is_lhs, dst_k, src_k) =
                    if t1 && !t0 { (false, 1, 0) } else { (true, 0, 1) };
                if t0 || t1 || self.arr(comp, si, 0, regs)?.numel() >= ops::ELEM_PAR_MIN {
                    let mut dst = self.fetch(comp, si, dst_k, regs).into_array()?;
                    let src = self.arr(comp, si, src_k, regs)?;
                    ensure!(
                        dst.dims == src.dims,
                        "binary {b:?} shape mismatch {:?} vs {:?} \
                         (HLO has no implicit broadcast)",
                        dst.dims,
                        src.dims
                    );
                    ops::binary_inplace_sharded(
                        *b,
                        dst_is_lhs,
                        dst.buf_mut(),
                        &src.buf,
                        self.threads,
                    )?;
                    Value::Array(dst)
                } else {
                    Value::Array(ops::binary(
                        *b,
                        self.arr(comp, si, 0, regs)?,
                        self.arr(comp, si, 1, regs)?,
                    )?)
                }
            }
            Op::Dot(nums) => {
                let lhs = self.arr(comp, si, 0, regs)?;
                let rhs = self.arr(comp, si, 1, regs)?;
                Value::Array(self.dot_packed(lhs, rhs, nums)?)
            }
            Op::Gather(g) => {
                let (_, out_dims) = ins.shape.array()?;
                Value::Array(ops::gather(
                    self.arr(comp, si, 0, regs)?,
                    self.arr(comp, si, 1, regs)?,
                    g,
                    out_dims,
                )?)
            }
            Op::Reduce { dims, comp: target } => match &comp.fused[si] {
                Fused::Bin { op, acc_first } => {
                    self.reduce_fused(ins, regs, *op, *acc_first)?
                }
                _ => self.reduce_generic(ins, regs, dims, *target)?,
            },
            Op::Scatter { dims, comp: target } => {
                ensure!(ins.operands.len() == 3, "variadic scatter unsupported");
                match &comp.fused[si] {
                    Fused::Bin { op, acc_first } => {
                        self.scatter_fused(comp, si, regs, dims, *op, *acc_first)?
                    }
                    _ => self.scatter_generic(comp, si, regs, dims, *target)?,
                }
            }
            Op::Convolution(d) => {
                let lhs = self.arr(comp, si, 0, regs)?;
                let rhs = self.arr(comp, si, 1, regs)?;
                Value::Array(ops::conv(lhs, rhs, d, self.threads)?)
            }
            Op::Reverse { dims } => {
                Value::Array(ops::reverse(self.arr(comp, si, 0, regs)?, dims)?)
            }
            Op::ReduceWindow { window, comp: target } => {
                ensure!(ins.operands.len() == 2, "variadic reduce-window unsupported");
                match &comp.fused[si] {
                    Fused::Bin { op, acc_first } => {
                        Value::Array(ops::reduce_window_fused(
                            self.arr(comp, si, 0, regs)?,
                            self.arr(comp, si, 1, regs)?,
                            window,
                            *op,
                            *acc_first,
                            self.threads,
                        )?)
                    }
                    _ => self.reduce_window_generic(ins, regs, window, *target)?,
                }
            }
        })
    }

    // ------------------------------------------------------------ dot ---

    /// General dot via packed contiguous panels and a lane-blocked,
    /// register-tiled microkernel: the rhs panel is transposed into
    /// `LANE_BLOCK`-wide `[kn][8]` tiles and each lhs row contracts
    /// eight output columns at once ([`dot_lanes`]), with remainder
    /// columns on the scalar 4-way dot. Every output element performs
    /// the identical operation order to [`ops::dot`] (stride-4 partial
    /// sums combined as `(s0+s1)+(s2+s3)`, sequential tail), so results
    /// match it bit-for-bit at any thread count.
    fn dot_packed(&self, lhs: &ArrayValue, rhs: &ArrayValue, nums: &DotDims) -> Result<ArrayValue> {
        let x = lhs.as_f32()?;
        let y = rhs.as_f32()?;
        ensure!(nums.lhs_batch.len() == nums.rhs_batch.len(), "dot batch arity mismatch");
        ensure!(
            nums.lhs_contracting.len() == nums.rhs_contracting.len(),
            "dot contracting arity mismatch"
        );
        let lfree: Vec<usize> = (0..lhs.dims.len())
            .filter(|d| !nums.lhs_batch.contains(d) && !nums.lhs_contracting.contains(d))
            .collect();
        let rfree: Vec<usize> = (0..rhs.dims.len())
            .filter(|d| !nums.rhs_batch.contains(d) && !nums.rhs_contracting.contains(d))
            .collect();
        let mut out_dims: Vec<usize> = nums.lhs_batch.iter().map(|&d| lhs.dims[d]).collect();
        out_dims.extend(lfree.iter().map(|&d| lhs.dims[d]));
        out_dims.extend(rfree.iter().map(|&d| rhs.dims[d]));
        for (t, &d) in nums.lhs_batch.iter().enumerate() {
            ensure!(
                rhs.dims[nums.rhs_batch[t]] == lhs.dims[d],
                "dot batch dim mismatch"
            );
        }
        let kdims: Vec<usize> = nums.lhs_contracting.iter().map(|&d| lhs.dims[d]).collect();
        for (i, &d) in nums.rhs_contracting.iter().enumerate() {
            ensure!(rhs.dims[d] == kdims[i], "dot contracting dim mismatch");
        }
        let bn: usize = nums.lhs_batch.iter().map(|&d| lhs.dims[d]).product();
        let mn: usize = lfree.iter().map(|&d| lhs.dims[d]).product();
        let nn: usize = rfree.iter().map(|&d| rhs.dims[d]).product();
        let total = bn * mn * nn;
        if total == 0 {
            return ArrayValue::new(out_dims, Buf::F32(Vec::new()));
        }
        let kn_raw: usize = kdims.iter().product();
        if !kdims.is_empty() && kn_raw == 0 {
            // empty contraction: every output is the empty sum
            return ArrayValue::new(out_dims, Buf::F32(vec![0.0; total]));
        }
        let kn = kn_raw.max(1);

        let lp = pack_f32(x, &lhs.dims, &nums.lhs_batch, &lfree, &nums.lhs_contracting);
        let rp = pack_f32(y, &rhs.dims, &nums.rhs_batch, &rfree, &nums.rhs_contracting);
        let rt = tile_rhs(&rp, bn, nn, kn);
        let rows = bn * mn;
        let mut out = vec![0.0f32; total];
        let workers =
            if total >= DOT_PAR_MIN && self.threads > 1 { self.threads.min(rows) } else { 1 };
        if workers <= 1 {
            dot_rows(&lp, &rp, &rt, mn, nn, kn, 0, &mut out);
        } else {
            let chunk_rows = rows.div_ceil(workers);
            let (lp, rp, rt) = (&lp, &rp, &rt);
            std::thread::scope(|s| {
                for (ci, chunk) in out.chunks_mut(chunk_rows * nn).enumerate() {
                    s.spawn(move || dot_rows(lp, rp, rt, mn, nn, kn, ci * chunk_rows, chunk));
                }
            });
        }
        ArrayValue::new(out_dims, Buf::F32(out))
    }

    // --------------------------------------------------------- reduce ---

    /// Fused single-input reduce whose region is one scalar binary op.
    /// Identical visit order to the generic path: output cells in
    /// ascending flat order, reduced elements in ascending row-major
    /// order within each cell. Output cells shard across workers above
    /// a size threshold and merge in ascending order
    /// ([`ops::fold_cells`]) — bit-identical at any thread count.
    fn reduce_fused(
        &self,
        ins: &Instr,
        regs: &[Option<Value>],
        op: BinaryOp,
        acc_first: bool,
    ) -> Result<Value> {
        let x = regs[ins.operands[0]].as_ref().expect("operand").array()?;
        let init = regs[ins.operands[1]].as_ref().expect("operand").array()?;
        ensure!(init.numel() == 1, "reduce init must be scalar");
        let dims = match &ins.op {
            Op::Reduce { dims, .. } => dims,
            _ => unreachable!("reduce_fused on non-reduce"),
        };
        let g = ops::ReduceGeom::new(&x.dims, dims);
        let w = self.threads;
        let buf = match (&*x.buf, &*init.buf) {
            (Buf::F32(xs), Buf::F32(is)) => {
                let step =
                    |a, v| if acc_first { f32_bin(op, a, v) } else { f32_bin(op, v, a) };
                Buf::F32(ops::fold_cells(&g, xs, is[0], step, w)?)
            }
            (Buf::S32(xs), Buf::S32(is)) => {
                let step =
                    |a, v| if acc_first { s32_bin(op, a, v) } else { s32_bin(op, v, a) };
                Buf::S32(ops::fold_cells(&g, xs, is[0], step, w)?)
            }
            (Buf::U32(xs), Buf::U32(is)) => {
                let step =
                    |a, v| if acc_first { u32_bin(op, a, v) } else { u32_bin(op, v, a) };
                Buf::U32(ops::fold_cells(&g, xs, is[0], step, w)?)
            }
            (Buf::Pred(xs), Buf::Pred(is)) => {
                let f = pred_bin(op)?;
                let step = |a, v| -> Result<bool> {
                    Ok(if acc_first { f(a, v) } else { f(v, a) })
                };
                Buf::Pred(ops::fold_cells(&g, xs, is[0], step, w)?)
            }
            _ => bail!("reduce input/init type mismatch"),
        };
        Ok(Value::Array(ArrayValue::new(g.out_dims, buf)?))
    }

    // ------------------------------------------------- fused loops ---

    /// Counted-`while` superinstruction (see
    /// [`crate::runtime::interp::fuse`]): read the trip count from the
    /// incoming state, unpack the state tuple once into per-element
    /// slots, then per iteration run only the body's compute steps —
    /// the state reads become direct register writes, the root tuple
    /// becomes direct register reads, and the condition never runs.
    /// Execute one elementwise-chain superinstruction: splat the
    /// folded scalars, borrow the full input lanes, and run the
    /// compiled tape once per output element ([`ops::chain_apply`]).
    /// When the spec names an in-place slot, that register is moved
    /// out and overwritten (copy-on-write if its buffer is shared);
    /// its previous values reach the tape through [`ops::LaneRef::Dst`]
    /// — read per element before the element's store, so the rewrite
    /// is bit-identical to the standalone steps.
    fn chain_exec(
        &self,
        comp: &CompPlan,
        si: usize,
        spec: &fuse::ChainSpec,
        regs: &mut [Option<Value>],
    ) -> Result<Value> {
        let (ty, dims) = comp.instrs[si].shape.array()?;
        let mut dst = match spec.inplace {
            Some(slot) => {
                let r = spec.inputs[slot].reg();
                let v = regs[r].take().context("chain in-place operand register")?;
                let a = v.into_array()?;
                ensure!(
                    a.ty() == ty && a.dims == dims,
                    "chain in-place operand shape mismatch"
                );
                a
            }
            None => {
                let n = dims.iter().product();
                let buf = match ty {
                    ElemType::F32 => Buf::F32(vec![0.0; n]),
                    ElemType::S32 => Buf::S32(vec![0; n]),
                    ElemType::U32 => Buf::U32(vec![0; n]),
                    ElemType::Pred => Buf::Pred(vec![false; n]),
                };
                ArrayValue { dims: dims.to_vec(), buf: std::sync::Arc::new(buf) }
            }
        };
        let mut lanes = Vec::with_capacity(spec.inputs.len());
        for (i, inp) in spec.inputs.iter().enumerate() {
            if spec.inplace == Some(i) {
                lanes.push(ops::LaneRef::Dst);
                continue;
            }
            let a = regs[inp.reg()].as_ref().context("chain operand register")?.array()?;
            lanes.push(match *inp {
                fuse::ChainInput::Full(_) => {
                    ensure!(a.dims == dims, "chain input shape mismatch");
                    match &*a.buf {
                        Buf::F32(x) => ops::LaneRef::F32(x),
                        Buf::S32(x) => ops::LaneRef::S32(x),
                        Buf::U32(x) => ops::LaneRef::U32(x),
                        Buf::Pred(x) => ops::LaneRef::Pred(x),
                    }
                }
                fuse::ChainInput::Scalar(_) => {
                    ensure!(a.numel() == 1, "chain splat source must be one element");
                    ops::LaneRef::Splat(match &*a.buf {
                        Buf::F32(x) => x[0].to_bits(),
                        Buf::S32(x) => x[0] as u32,
                        Buf::U32(x) => x[0],
                        Buf::Pred(x) => x[0] as u32,
                    })
                }
            });
        }
        ops::chain_apply(&spec.tape, &lanes, dst.buf_mut(), self.threads)?;
        Ok(Value::Array(dst))
    }

    fn counted_loop(&self, spec: &CountedLoop, init: Value) -> Result<Value> {
        let body = &self.plan.comps[spec.body];
        let state = match init {
            Value::Tuple(vs) => vs,
            Value::Array(_) => bail!("counted while state must be a tuple"),
        };
        ensure!(state.len() == spec.arity, "counted while arity mismatch");
        let mut state: Vec<Option<Value>> = state.into_iter().map(Some).collect();
        let counter = state[spec.idx].as_ref().expect("state slot").array()?;
        ensure!(counter.numel() == 1, "counted while counter must be scalar");
        let start = counter.buf.index_at(0)?;
        let trips = (spec.bound - start).max(0);
        for _ in 0..trips {
            let mut regs: Vec<Option<Value>> =
                (0..body.instrs.len()).map(|_| None).collect();
            for (k, &(gi, e)) in spec.state_reads.iter().enumerate() {
                let v = if spec.take_state[k] {
                    state[e].take()
                } else {
                    state[e].clone()
                };
                regs[gi] = Some(v.expect("state slot populated"));
            }
            for &si in &spec.steps {
                if matches!(body.fused[si], Fused::ChainInterior { .. }) {
                    continue; // elided into a chain within the body
                }
                let v = self.exec_step(body, si, &mut regs, &mut []).with_context(|| {
                    format!("executing {}::{}", body.name, body.instrs[si].name)
                })?;
                regs[si] = Some(v);
                for &r in &body.free_after[si] {
                    regs[r] = None;
                }
            }
            let mut next: Vec<Option<Value>> = Vec::with_capacity(spec.arity);
            for (k, &o) in spec.root_ops.iter().enumerate() {
                let v = if body.take[body.root][k] {
                    regs[o].take()
                } else {
                    regs[o].clone()
                };
                next.push(Some(v.expect("root operand register computed")));
            }
            state = next;
        }
        Ok(Value::Tuple(state.into_iter().map(|v| v.expect("state slot")).collect()))
    }

    /// Native threefry-2x32 round-group call: the argument order
    /// `(i, x0, x1, k0, k1, k2, rot_a, rot_b)` and the output
    /// permutation `(i+1, x0', x1', k1, k2, k0, rot_b, rot_a)` were
    /// verified structurally by [`fuse::match_threefry`] at plan time.
    fn threefry_call(
        &self,
        comp: &CompPlan,
        si: usize,
        regs: &mut [Option<Value>],
    ) -> Result<Value> {
        ensure!(comp.instrs[si].operands.len() == 8, "threefry call arity");
        let mut vals = Vec::with_capacity(8);
        for k in 0..8 {
            vals.push(self.fetch(comp, si, k, regs));
        }
        let mut it = vals.into_iter();
        let mut next = move || it.next().expect("eight operands");
        let i_arr = next().into_array()?;
        let mut x0 = next().into_array()?;
        let mut x1 = next().into_array()?;
        let k0 = next();
        let k1 = next();
        let k2 = next();
        let rot_a = next();
        let rot_b = next();
        let i0 = match &*i_arr.buf {
            Buf::S32(v) if v.len() == 1 => v[0],
            _ => bail!("threefry round counter must be a scalar s32"),
        };
        let new_i = i0.wrapping_add(1);
        let rot: [u32; 4] =
            rot_a.array()?.as_u32()?.try_into().context("threefry rotation arity")?;
        let k0a = k0.array()?;
        let k1a = k1.array()?;
        ensure!(k0a.numel() == 1 && k1a.numel() == 1, "threefry keys must be scalar");
        let k0v = k0a.as_u32()?[0];
        // (x1 + k1) + (i+1) regrouped to x1 + (k1 + (i+1)): u32
        // wrapping addition is associative, so this is bit-exact
        let kx1 = k1a.as_u32()?[0].wrapping_add(new_i as u32);
        ensure!(x0.dims == x1.dims, "threefry lane shape mismatch");
        ops::threefry2x32(
            x0.buf_mut().as_u32_mut()?,
            x1.buf_mut().as_u32_mut()?,
            &rot,
            k0v,
            kx1,
            self.threads,
        )?;
        Ok(Value::Tuple(vec![
            Value::Array(ArrayValue::new(vec![], Buf::S32(vec![new_i]))?),
            Value::Array(x0),
            Value::Array(x1),
            k1,
            k2,
            k0,
            rot_b,
            rot_a,
        ]))
    }

    /// (Variadic) reduce fallback: invoke the region per fold step.
    /// Mirrors the reference evaluator's visit order exactly.
    fn reduce_generic(
        &self,
        ins: &Instr,
        regs: &[Option<Value>],
        dims: &[usize],
        target: usize,
    ) -> Result<Value> {
        let nops = ins.operands.len();
        ensure!(nops >= 2 && nops % 2 == 0, "reduce needs N inputs + N inits");
        let nin = nops / 2;
        let inputs: Vec<&ArrayValue> = ins.operands[..nin]
            .iter()
            .map(|&o| regs[o].as_ref().expect("operand").array())
            .collect::<Result<_>>()?;
        let inits: Vec<&ArrayValue> = ins.operands[nin..]
            .iter()
            .map(|&o| regs[o].as_ref().expect("operand").array())
            .collect::<Result<_>>()?;
        let x0 = inputs[0];
        for x in &inputs {
            ensure!(x.dims == x0.dims, "reduce input shape mismatch");
        }
        let g = ops::ReduceGeom::new(&x0.dims, dims);

        let mut outs: Vec<Buf> = inits.iter().map(|a| Buf::with_capacity(a.ty(), g.n)).collect();
        let (mut oi, mut ri) = g.scratch();
        for f in 0..g.n {
            let base = g.cell_base(f, &mut oi);
            let mut accs: Vec<Value> =
                inits.iter().map(|a| Value::Array(a.scalar_at(0))).collect();
            for rf in 0..g.rn {
                let xi = g.elem_index(base, rf, &mut ri);
                let mut cargs = accs;
                for x in &inputs {
                    cargs.push(Value::Array(x.scalar_at(xi)));
                }
                let res = self.run(target, cargs)?;
                accs = match res {
                    Value::Tuple(vs) => vs,
                    v => vec![v],
                };
                ensure!(accs.len() == nin, "reduce region arity mismatch");
            }
            for (o, acc) in outs.iter_mut().zip(&accs) {
                o.push_from(&acc.array()?.buf, 0);
            }
        }
        let mut results: Vec<Value> = outs
            .into_iter()
            .map(|buf| ArrayValue::new(g.out_dims.clone(), buf).map(Value::Array))
            .collect::<Result<_>>()?;
        if matches!(ins.shape, Shape::Tuple(_)) {
            Ok(Value::Tuple(results))
        } else {
            ensure!(results.len() == 1, "reduce arity/shape mismatch");
            Ok(results.swap_remove(0))
        }
    }

    // -------------------------------------------------------- scatter ---

    /// Fused scatter whose region is one scalar binary op: accumulate
    /// straight into the operand buffer (stolen in place when the
    /// operand dies here, CoW-cloned otherwise).
    fn scatter_fused(
        &self,
        comp: &CompPlan,
        si: usize,
        regs: &mut [Option<Value>],
        s: &ScatterDims,
        op: BinaryOp,
        acc_first: bool,
    ) -> Result<Value> {
        let mut operand = self.fetch(comp, si, 0, regs).into_array()?;
        let ins = &comp.instrs[si];
        let indices = regs[ins.operands[1]].as_ref().expect("operand").array()?;
        let updates = regs[ins.operands[2]].as_ref().expect("operand").array()?;
        let operand_dims = operand.dims.clone();
        let out = operand.buf_mut();
        match (out, &*updates.buf) {
            (Buf::F32(o), Buf::F32(u)) => {
                ops::scatter_walk(&operand_dims, indices, updates, s, |pi, f| {
                    let (a, b) = if acc_first { (o[pi], u[f]) } else { (u[f], o[pi]) };
                    o[pi] = f32_bin(op, a, b)?;
                    Ok(())
                })?
            }
            (Buf::S32(o), Buf::S32(u)) => {
                ops::scatter_walk(&operand_dims, indices, updates, s, |pi, f| {
                    let (a, b) = if acc_first { (o[pi], u[f]) } else { (u[f], o[pi]) };
                    o[pi] = s32_bin(op, a, b)?;
                    Ok(())
                })?
            }
            (Buf::U32(o), Buf::U32(u)) => {
                ops::scatter_walk(&operand_dims, indices, updates, s, |pi, f| {
                    let (a, b) = if acc_first { (o[pi], u[f]) } else { (u[f], o[pi]) };
                    o[pi] = u32_bin(op, a, b)?;
                    Ok(())
                })?
            }
            (Buf::Pred(o), Buf::Pred(u)) => {
                let fun = pred_bin(op)?;
                ops::scatter_walk(&operand_dims, indices, updates, s, |pi, f| {
                    let (a, b) = if acc_first { (o[pi], u[f]) } else { (u[f], o[pi]) };
                    o[pi] = fun(a, b);
                    Ok(())
                })?
            }
            _ => bail!("scatter operand/update type mismatch"),
        }
        Ok(Value::Array(operand))
    }

    /// Scatter fallback: invoke the region per update. Mirrors the
    /// reference evaluator exactly.
    /// Generic `reduce-window`: serial per-cell region invocation — the
    /// fallback when the region is not a single scalar binary op.
    /// Identical tap visit order to the fused path and the reference
    /// walker (the geometry lives in [`ops::WindowGeom`]).
    fn reduce_window_generic(
        &self,
        ins: &Instr,
        regs: &[Option<Value>],
        window: &[WindowDim],
        target: usize,
    ) -> Result<Value> {
        let x = regs[ins.operands[0]].as_ref().expect("operand").array()?;
        let init = regs[ins.operands[1]].as_ref().expect("operand").array()?;
        ensure!(init.dims.is_empty(), "reduce-window init must be scalar");
        let g = ops::WindowGeom::new(&x.dims, window)?;
        let (mut oi, mut wi) = g.scratch();
        let mut out = Buf::with_capacity(init.ty(), g.n);
        for f in 0..g.n {
            g.cell_coords(f, &mut oi);
            let mut acc = Value::Array(init.scalar_at(0));
            for wf in 0..g.wn {
                if let Some(xi) = g.tap_index(&oi, wf, &mut wi) {
                    let val = Value::Array(x.scalar_at(xi));
                    acc = self.run(target, vec![acc, val])?;
                }
            }
            out.push_from(&acc.array()?.buf, 0);
        }
        Ok(Value::Array(ArrayValue::new(g.out_dims.clone(), out)?))
    }

    fn scatter_generic(
        &self,
        comp: &CompPlan,
        si: usize,
        regs: &mut [Option<Value>],
        s: &ScatterDims,
        target: usize,
    ) -> Result<Value> {
        let operand = self.fetch(comp, si, 0, regs).into_array()?;
        let ins = &comp.instrs[si];
        let indices = regs[ins.operands[1]].as_ref().expect("operand").array()?;
        let updates = regs[ins.operands[2]].as_ref().expect("operand").array()?;
        let operand_dims = operand.dims.clone();
        let mut out = (*operand.buf).clone();
        let ty = out.ty();
        ops::scatter_walk(&operand_dims, indices, updates, s, |pi, f| {
            let cur = {
                let mut b = Buf::with_capacity(ty, 1);
                b.push_from(&out, pi);
                Value::Array(ArrayValue::new(vec![], b)?)
            };
            let upd = Value::Array(updates.scalar_at(f));
            let res = self.run(target, vec![cur, upd])?;
            out.set_from(pi, &res.array()?.buf, 0);
            Ok(())
        })?;
        Ok(Value::Array(ArrayValue::new(operand_dims, out)?))
    }
}

// ------------------------------------------------------- dot helpers ---

/// Flat source offsets of every coordinate of `group` (original dim
/// indices, iterated row-major in list order).
fn group_offsets(dims: &[usize], st: &[usize], group: &[usize]) -> Vec<usize> {
    let sizes: Vec<usize> = group.iter().map(|&d| dims[d]).collect();
    let n: usize = sizes.iter().product::<usize>().max(1);
    let mut offs = Vec::with_capacity(n);
    let mut idx = vec![0usize; group.len()];
    for _ in 0..n {
        let off: usize = idx.iter().zip(group).map(|(&c, &d)| c * st[d]).sum();
        offs.push(off);
        for t in (0..group.len()).rev() {
            idx[t] += 1;
            if idx[t] < sizes[t] {
                break;
            }
            idx[t] = 0;
        }
    }
    offs
}

/// Pack `src` into a contiguous `[outer][mid][inner]` panel.
fn pack_f32(
    src: &[f32],
    dims: &[usize],
    outer: &[usize],
    mid: &[usize],
    inner: &[usize],
) -> Vec<f32> {
    let st = strides_of(dims);
    let oo = group_offsets(dims, &st, outer);
    let mo = group_offsets(dims, &st, mid);
    let io = group_offsets(dims, &st, inner);
    let mut out = Vec::with_capacity(oo.len() * mo.len() * io.len());
    for &a in &oo {
        for &b in &mo {
            let base = a + b;
            for &c in &io {
                out.push(src[base + c]);
            }
        }
    }
    out
}

/// Output columns per register tile in the blocked dot kernel — the
/// `dot8` transposed-tile width from `quant/assign.rs`, generalized
/// here to the packed `[batch][free][k]` dot.
const LANE_BLOCK: usize = 8;

/// Transpose the packed rhs panel `[bn][nn][kn]` into lane-major tiles
/// `[bn][nn / LANE_BLOCK][kn][LANE_BLOCK]` (full blocks only; the
/// `nn % LANE_BLOCK` remainder columns stay row-major in the packed
/// panel and are contracted by the scalar 4-way dot).
fn tile_rhs(rp: &[f32], bn: usize, nn: usize, kn: usize) -> Vec<f32> {
    let nblk = nn / LANE_BLOCK;
    let mut tiles = vec![0f32; bn * nblk * kn * LANE_BLOCK];
    for b in 0..bn {
        let rb = &rp[b * nn * kn..(b + 1) * nn * kn];
        let tb = &mut tiles[b * nblk * kn * LANE_BLOCK..(b + 1) * nblk * kn * LANE_BLOCK];
        for blk in 0..nblk {
            for t in 0..kn {
                for l in 0..LANE_BLOCK {
                    tb[(blk * kn + t) * LANE_BLOCK + l] = rb[(blk * LANE_BLOCK + l) * kn + t];
                }
            }
        }
    }
    tiles
}

/// Eight output columns at once against one transposed `[kn][8]` tile.
/// Per lane this performs *exactly* the operation sequence of
/// [`assign::dot`] / the rewritten [`ops::dot`] (four stride-4 partial
/// sums combined as `(s0+s1)+(s2+s3)`, then a sequential tail), so
/// `out[l]` matches the scalar contraction bit-for-bit.
#[inline]
fn dot_lanes(xr: &[f32], tile: &[f32], kn: usize, out: &mut [f32; LANE_BLOCK]) {
    let mut s0 = [0f32; LANE_BLOCK];
    let mut s1 = [0f32; LANE_BLOCK];
    let mut s2 = [0f32; LANE_BLOCK];
    let mut s3 = [0f32; LANE_BLOCK];
    let kn4 = kn - kn % 4;
    let mut t = 0;
    while t < kn4 {
        let r0 = &tile[t * LANE_BLOCK..(t + 1) * LANE_BLOCK];
        let r1 = &tile[(t + 1) * LANE_BLOCK..(t + 2) * LANE_BLOCK];
        let r2 = &tile[(t + 2) * LANE_BLOCK..(t + 3) * LANE_BLOCK];
        let r3 = &tile[(t + 3) * LANE_BLOCK..(t + 4) * LANE_BLOCK];
        for l in 0..LANE_BLOCK {
            s0[l] += xr[t] * r0[l];
            s1[l] += xr[t + 1] * r1[l];
            s2[l] += xr[t + 2] * r2[l];
            s3[l] += xr[t + 3] * r3[l];
        }
        t += 4;
    }
    for l in 0..LANE_BLOCK {
        out[l] = (s0[l] + s1[l]) + (s2[l] + s3[l]);
    }
    while t < kn {
        let r = &tile[t * LANE_BLOCK..(t + 1) * LANE_BLOCK];
        for l in 0..LANE_BLOCK {
            out[l] += xr[t] * r[l];
        }
        t += 1;
    }
}

/// Contract packed panels over rows `[row0, row0 + out.len()/nn)`.
/// Full `LANE_BLOCK`-wide column tiles go through the transposed-tile
/// lane kernel; remainder columns use the scalar 4-way dot — both
/// reproduce [`ops::dot`]'s accumulation order per output element.
fn dot_rows(
    lp: &[f32],
    rp: &[f32],
    rt: &[f32],
    mn: usize,
    nn: usize,
    kn: usize,
    row0: usize,
    out: &mut [f32],
) {
    let nblk = nn / LANE_BLOCK;
    for (r, orow) in out.chunks_mut(nn).enumerate() {
        let row = row0 + r;
        let b = row / mn;
        let xr = &lp[row * kn..(row + 1) * kn];
        let tb = &rt[b * nblk * kn * LANE_BLOCK..(b + 1) * nblk * kn * LANE_BLOCK];
        for blk in 0..nblk {
            let tile = &tb[blk * kn * LANE_BLOCK..(blk + 1) * kn * LANE_BLOCK];
            let mut lanes = [0f32; LANE_BLOCK];
            dot_lanes(xr, tile, kn, &mut lanes);
            orow[blk * LANE_BLOCK..(blk + 1) * LANE_BLOCK].copy_from_slice(&lanes);
        }
        let rb = &rp[b * nn * kn..(b + 1) * nn * kn];
        for (j, o) in orow.iter_mut().enumerate().skip(nblk * LANE_BLOCK) {
            *o = assign::dot(xr, &rb[j * kn..(j + 1) * kn]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::interp::eval::Interp;
    use crate::runtime::interp::parser::parse_module;
    use crate::util::rng::Pcg;

    fn randv(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Pcg::new(seed);
        (0..n).map(|_| r.next_normal()).collect()
    }

    fn fv(dims: &[usize], data: Vec<f32>) -> ArrayValue {
        ArrayValue::f32(dims, data).unwrap()
    }

    /// Planned and tree-walked outputs must agree bit-for-bit.
    fn assert_same(text: &str, args: &[Value], threads: usize) -> Value {
        let m = parse_module(text).unwrap();
        let want = Interp::new(&m).run_entry(args).unwrap();
        let plan = Plan::compile(&m);
        let got = plan.run_entry(args.to_vec(), threads).unwrap();
        assert_eq!(got, want);
        got
    }

    #[test]
    fn dot_packed_matches_reference_shapes() {
        let plan = Plan { comps: Vec::new(), entry: 0, entry_params: Vec::new(), stats: None };
        let ex = Executor { plan: &plan, threads: 1 };
        // (lhs dims, rhs dims, dot dims)
        let cases: Vec<(Vec<usize>, Vec<usize>, DotDims)> = vec![
            // plain matmul
            (
                vec![5, 7],
                vec![7, 3],
                DotDims {
                    lhs_contracting: vec![1],
                    rhs_contracting: vec![0],
                    ..Default::default()
                },
            ),
            // attention scores: contract last dim of both, batch [0,1]
            (
                vec![2, 3, 4, 6],
                vec![2, 3, 5, 6],
                DotDims {
                    lhs_batch: vec![0, 1],
                    rhs_batch: vec![0, 1],
                    lhs_contracting: vec![3],
                    rhs_contracting: vec![3],
                },
            ),
            // attention mix: contract a middle dim of rhs
            (
                vec![2, 3, 4, 5],
                vec![2, 3, 5, 6],
                DotDims {
                    lhs_batch: vec![0, 1],
                    rhs_batch: vec![0, 1],
                    lhs_contracting: vec![3],
                    rhs_contracting: vec![2],
                },
            ),
            // multi-dim contraction, non-adjacent dims
            (
                vec![3, 4, 5],
                vec![4, 2, 3],
                DotDims {
                    lhs_contracting: vec![1, 0],
                    rhs_contracting: vec![0, 2],
                    ..Default::default()
                },
            ),
            // outer product: no contraction at all
            (vec![3], vec![4], DotDims::default()),
            // scalar-ish: rank-1 dot rank-1 full contraction
            (
                vec![6],
                vec![6],
                DotDims {
                    lhs_contracting: vec![0],
                    rhs_contracting: vec![0],
                    ..Default::default()
                },
            ),
        ];
        for (i, (ld, rd, nums)) in cases.into_iter().enumerate() {
            let lhs = fv(&ld, randv(i as u64 + 1, ld.iter().product()));
            let rhs = fv(&rd, randv(i as u64 + 100, rd.iter().product()));
            let want = ops::dot(&lhs, &rhs, &nums).unwrap();
            let got = ex.dot_packed(&lhs, &rhs, &nums).unwrap();
            assert_eq!(got.dims, want.dims, "case {i}");
            let (g, w) = (got.as_f32().unwrap(), want.as_f32().unwrap());
            for (a, b) in g.iter().zip(w) {
                assert_eq!(a.to_bits(), b.to_bits(), "case {i}");
            }
        }
    }

    #[test]
    fn dot_packed_sharded_is_bit_identical() {
        let plan = Plan { comps: Vec::new(), entry: 0, entry_params: Vec::new(), stats: None };
        // above DOT_PAR_MIN so the threaded path actually engages
        let lhs = fv(&[96, 48], randv(1, 96 * 48));
        let rhs = fv(&[48, 64], randv(2, 48 * 64));
        let nums = DotDims {
            lhs_contracting: vec![1],
            rhs_contracting: vec![0],
            ..Default::default()
        };
        let base = Executor { plan: &plan, threads: 1 }.dot_packed(&lhs, &rhs, &nums).unwrap();
        for threads in [2usize, 3, 8] {
            let got =
                Executor { plan: &plan, threads }.dot_packed(&lhs, &rhs, &nums).unwrap();
            assert_eq!(got, base, "threads={threads}");
        }
    }

    #[test]
    fn fused_sum_reduce_matches_tree_walk() {
        let text = "HloModule t\n\nregion_0.1 {\n  a.1 = f32[] parameter(0)\n  \
                    b.2 = f32[] parameter(1)\n  ROOT add.3 = f32[] add(a.1, b.2)\n}\n\n\
                    ENTRY main.1 {\n  x.1 = f32[2,3]{1,0} parameter(0)\n  \
                    c.2 = f32[] constant(0)\n  ROOT r.3 = f32[2]{0} reduce(x.1, c.2), \
                    dimensions={1}, to_apply=region_0.1\n}\n";
        let m = parse_module(text).unwrap();
        let plan = Plan::compile(&m);
        assert_eq!(plan.comps[1].fused[2], Fused::Bin { op: BinaryOp::Add, acc_first: true });
        let args = vec![Value::Array(fv(&[2, 3], randv(3, 6)))];
        assert_same(text, &args, 1);
    }

    #[test]
    fn fused_max_reduce_non_trailing_dims() {
        // reduce over a LEADING dim: exercises the strided fold path
        let text = "HloModule t\n\nregion_0.1 {\n  a.1 = f32[] parameter(0)\n  \
                    b.2 = f32[] parameter(1)\n  ROOT m.3 = f32[] maximum(b.2, a.1)\n}\n\n\
                    ENTRY main.1 {\n  x.1 = f32[4,3]{1,0} parameter(0)\n  \
                    c.2 = f32[] constant(-inf)\n  ROOT r.3 = f32[3]{0} reduce(x.1, c.2), \
                    dimensions={0}, to_apply=region_0.1\n}\n";
        let m = parse_module(text).unwrap();
        let plan = Plan::compile(&m);
        // operand order in the region is (elem, acc)
        assert_eq!(plan.comps[1].fused[2], Fused::Bin { op: BinaryOp::Max, acc_first: false });
        let args = vec![Value::Array(fv(&[4, 3], randv(5, 12)))];
        assert_same(text, &args, 1);
    }

    #[test]
    fn fused_max_pool_reduce_window_matches_tree_walk() {
        // stride-2 SAME max pool: the region fuses to Bin{Max} and the
        // planned fold must match the region-invoking tree walk bitwise
        let text = "HloModule t\n\nregion_0.1 {\n  a.1 = f32[] parameter(0)\n  \
                    b.2 = f32[] parameter(1)\n  ROOT m.3 = f32[] maximum(a.1, b.2)\n}\n\n\
                    ENTRY main.1 {\n  x.1 = f32[2,7]{1,0} parameter(0)\n  \
                    c.2 = f32[] constant(-inf)\n  ROOT r.3 = f32[2,4]{1,0} \
                    reduce-window(x.1, c.2), window={size=1x2 stride=1x2 pad=0_0x0_1}, \
                    to_apply=region_0.1\n}\n";
        let m = parse_module(text).unwrap();
        let plan = Plan::compile(&m);
        assert_eq!(plan.comps[1].fused[2], Fused::Bin { op: BinaryOp::Max, acc_first: true });
        assert_eq!(plan.fusion_stats().fused_windows, 1);
        let args = vec![Value::Array(fv(&[2, 7], randv(9, 14)))];
        for threads in [1usize, 3, 8] {
            assert_same(text, &args, threads);
        }
    }

    #[test]
    fn generic_reduce_window_region_matches_tree_walk() {
        // 4-instruction region (sum of squares): stays on the generic
        // per-tap region path
        let text = "HloModule t\n\nsq.1 {\n  a.1 = f32[] parameter(0)\n  \
                    b.2 = f32[] parameter(1)\n  m.3 = f32[] multiply(b.2, b.2)\n  \
                    ROOT r.4 = f32[] add(a.1, m.3)\n}\n\n\
                    ENTRY main.1 {\n  x.1 = f32[6]{0} parameter(0)\n  \
                    z.2 = f32[] constant(0)\n  ROOT rw.3 = f32[3]{0} \
                    reduce-window(x.1, z.2), window={size=2 stride=2}, to_apply=sq.1\n}\n";
        let m = parse_module(text).unwrap();
        let plan = Plan::compile(&m);
        assert_eq!(plan.comps[1].fused[2], Fused::None);
        let args = vec![Value::Array(fv(&[6], randv(11, 6)))];
        assert_same(text, &args, 1);
    }

    #[test]
    fn conv_planned_matches_tree_walk_across_threads() {
        // strided NHWC conv with asymmetric padding and feature groups
        let text = "HloModule t\n\nENTRY main.1 {\n  x.1 = f32[2,9,9,4]{3,2,1,0} \
                    parameter(0)\n  w.2 = f32[3,3,2,4]{3,2,1,0} parameter(1)\n  \
                    ROOT c.3 = f32[2,5,5,4]{3,2,1,0} convolution(x.1, w.2), \
                    window={size=3x3 stride=2x2 pad=1_1x0_2}, \
                    dim_labels=b01f_01io->b01f, feature_group_count=2\n}\n";
        let args = vec![
            Value::Array(fv(&[2, 9, 9, 4], randv(21, 2 * 9 * 9 * 4))),
            Value::Array(fv(&[3, 3, 2, 4], randv(22, 3 * 3 * 2 * 4))),
        ];
        for threads in [1usize, 3, 8] {
            assert_same(text, &args, threads);
        }
    }

    #[test]
    fn variadic_argmax_stays_generic_and_matches() {
        let text = "HloModule t\n\nregion_0.1 {\n  av.1 = f32[] parameter(0)\n  \
                    ai.2 = s32[] parameter(1)\n  bv.3 = f32[] parameter(2)\n  \
                    bi.4 = s32[] parameter(3)\n  ge.5 = pred[] compare(av.1, bv.3), \
                    direction=GE\n  mv.6 = f32[] select(ge.5, av.1, bv.3)\n  \
                    mi.7 = s32[] select(ge.5, ai.2, bi.4)\n  \
                    ROOT t.8 = (f32[], s32[]) tuple(mv.6, mi.7)\n}\n\n\
                    ENTRY main.1 {\n  x.1 = f32[4]{0} parameter(0)\n  \
                    i.2 = s32[4]{0} iota(), iota_dimension=0\n  \
                    ninf.3 = f32[] constant(-inf)\n  z.4 = s32[] constant(0)\n  \
                    ROOT r.5 = (f32[], s32[]) reduce(x.1, i.2, ninf.3, z.4), \
                    dimensions={0}, to_apply=region_0.1\n}\n";
        let args = vec![Value::Array(fv(&[4], vec![1.0, 9.0, 3.0, 9.0]))];
        let out = assert_same(text, &args, 1);
        let parts = out.tuple().unwrap();
        assert_eq!(parts[0].array().unwrap().as_f32().unwrap(), &[9.0]);
    }

    #[test]
    fn fused_scatter_add_matches_tree_walk() {
        let text = "HloModule t\n\nadd_region.1 {\n  a.1 = f32[] parameter(0)\n  \
                    b.2 = f32[] parameter(1)\n  ROOT add.3 = f32[] add(a.1, b.2)\n}\n\n\
                    ENTRY main.1 {\n  op.1 = f32[3,2]{1,0} parameter(0)\n  \
                    idx.2 = s32[2,1]{1,0} parameter(1)\n  \
                    up.3 = f32[2,2]{1,0} parameter(2)\n  \
                    ROOT sc.4 = f32[3,2]{1,0} scatter(op.1, idx.2, up.3), \
                    update_window_dims={1}, inserted_window_dims={0}, \
                    scatter_dims_to_operand_dims={0}, index_vector_dim=1, \
                    to_apply=add_region.1\n}\n";
        let operand = Value::Array(fv(&[3, 2], vec![0.0; 6]));
        let idx = Value::Array(ArrayValue::i32(&[2, 1], vec![1, 7]).unwrap());
        let upd = Value::Array(fv(&[2, 2], vec![1.0, 2.0, 10.0, 20.0]));
        // index 7 out of bounds: dropped by both engines
        assert_same(text, &[operand, idx, upd], 1);
    }

    #[test]
    fn while_and_tuples_match_tree_walk() {
        let text = "HloModule t\n\ncond.1 {\n  s.1 = (s32[], s32[]) parameter(0)\n  \
                    i.2 = s32[] get-tuple-element(s.1), index=0\n  \
                    five.3 = s32[] constant(5)\n  ROOT lt.4 = pred[] compare(i.2, five.3), \
                    direction=LT\n}\n\nbody.1 {\n  s.1 = (s32[], s32[]) parameter(0)\n  \
                    i.2 = s32[] get-tuple-element(s.1), index=0\n  \
                    a.3 = s32[] get-tuple-element(s.1), index=1\n  \
                    one.4 = s32[] constant(1)\n  two.5 = s32[] constant(2)\n  \
                    i2.6 = s32[] add(i.2, one.4)\n  a2.7 = s32[] multiply(a.3, two.5)\n  \
                    ROOT t.8 = (s32[], s32[]) tuple(i2.6, a2.7)\n}\n\n\
                    ENTRY main.1 {\n  z.1 = s32[] constant(0)\n  one.2 = s32[] constant(1)\n  \
                    st.3 = (s32[], s32[]) tuple(z.1, one.2)\n  \
                    ROOT w.4 = (s32[], s32[]) while(st.3), condition=cond.1, body=body.1\n}\n";
        assert_same(text, &[], 1);
    }

    #[test]
    fn duplicate_operand_is_never_taken() {
        // add(x, x): the register is used twice in one step, so the
        // in-place path must not steal it
        let text = "HloModule t\n\nENTRY main.1 {\n  x.1 = f32[3]{0} parameter(0)\n  \
                    d.2 = f32[3]{0} add(x.1, x.1)\n  \
                    ROOT m.3 = f32[3]{0} multiply(d.2, d.2)\n}\n";
        let args = vec![Value::Array(fv(&[3], vec![1.0, -2.0, 0.5]))];
        let out = assert_same(text, &args, 1);
        assert_eq!(out.array().unwrap().as_f32().unwrap(), &[4.0, 16.0, 1.0]);
    }

    #[test]
    fn inplace_chain_never_corrupts_caller_args() {
        // p0 and p1 share one buffer; the executor's in-place chain on
        // p0's side must CoW rather than alias it
        let text = "HloModule t\n\nENTRY main.1 {\n  a.1 = f32[2]{0} parameter(0)\n  \
                    b.2 = f32[2]{0} parameter(1)\n  o.3 = f32[2]{0} constant({10, 20})\n  \
                    s.4 = f32[2]{0} add(a.1, o.3)\n  n.5 = f32[2]{0} negate(s.4)\n  \
                    ROOT r.6 = f32[2]{0} multiply(n.5, b.2)\n}\n";
        let shared = fv(&[2], vec![1.0, 2.0]);
        let args = vec![Value::Array(shared.clone()), Value::Array(shared.clone())];
        let out = assert_same(text, &args, 1);
        assert_eq!(out.array().unwrap().as_f32().unwrap(), &[-11.0, -44.0]);
        // the caller's buffer is untouched
        assert_eq!(shared.as_f32().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn constants_survive_repeated_runs() {
        // a while body folds a shared constant into state every
        // iteration; if in-place execution ever wrote through the
        // constant's buffer, the second run would diverge
        let text = "HloModule t\n\ncond.1 {\n  s.1 = (s32[], f32[2]) parameter(0)\n  \
                    i.2 = s32[] get-tuple-element(s.1), index=0\n  \
                    n.3 = s32[] constant(4)\n  ROOT lt.4 = pred[] compare(i.2, n.3), \
                    direction=LT\n}\n\nbody.1 {\n  s.1 = (s32[], f32[2]) parameter(0)\n  \
                    i.2 = s32[] get-tuple-element(s.1), index=0\n  \
                    v.3 = f32[2]{0} get-tuple-element(s.1), index=1\n  \
                    one.4 = s32[] constant(1)\n  c.5 = f32[2]{0} constant({0.5, 0.25})\n  \
                    i2.6 = s32[] add(i.2, one.4)\n  v2.7 = f32[2]{0} add(v.3, c.5)\n  \
                    ROOT t.8 = (s32[], f32[2]) tuple(i2.6, v2.7)\n}\n\n\
                    ENTRY main.1 {\n  z.1 = s32[] constant(0)\n  \
                    v0.2 = f32[2]{0} parameter(0)\n  \
                    st.3 = (s32[], f32[2]) tuple(z.1, v0.2)\n  \
                    ROOT w.4 = (s32[], f32[2]) while(st.3), condition=cond.1, body=body.1\n}\n";
        let m = parse_module(text).unwrap();
        let plan = Plan::compile(&m);
        let args = vec![Value::Array(fv(&[2], vec![0.0, 0.0]))];
        let a = plan.run_entry(args.clone(), 1).unwrap();
        let b = plan.run_entry(args.clone(), 1).unwrap();
        assert_eq!(a, b);
        let want = Interp::new(&m).run_entry(&args).unwrap();
        assert_eq!(a, want);
        let parts = a.tuple().unwrap();
        assert_eq!(parts[1].array().unwrap().as_f32().unwrap(), &[2.0, 1.0]);
    }

    #[test]
    fn reshape_shares_and_cow_protects() {
        // reshape is O(1) buffer sharing; the in-place negate on the
        // reshaped value must not mutate the still-live source
        let text = "HloModule t\n\nENTRY main.1 {\n  x.1 = f32[2,2]{1,0} parameter(0)\n  \
                    r.2 = f32[4]{0} reshape(x.1)\n  n.3 = f32[4]{0} negate(r.2)\n  \
                    s.4 = f32[2,2]{1,0} reshape(n.3)\n  \
                    ROOT a.5 = f32[2,2]{1,0} add(s.4, x.1)\n}\n";
        let args = vec![Value::Array(fv(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]))];
        let out = assert_same(text, &args, 1);
        assert_eq!(out.array().unwrap().as_f32().unwrap(), &[0.0; 4]);
    }

    #[test]
    fn entry_param_shapes_recorded() {
        let text = "HloModule t\n\nENTRY main.1 {\n  x.1 = f32[2,3]{1,0} parameter(0)\n  \
                    s.2 = s32[] parameter(1)\n  c.3 = f32[2,3]{1,0} add(x.1, x.1)\n  \
                    ROOT t.4 = (f32[2,3], s32[]) tuple(c.3, s.2)\n}\n";
        let plan = Plan::compile(&parse_module(text).unwrap());
        assert_eq!(plan.n_entry_params(), 2);
        let (ty, dims) = plan.entry_param_shape(0).unwrap().array().unwrap();
        assert_eq!((ty, dims), (crate::runtime::interp::value::ElemType::F32, &[2usize, 3][..]));
        assert!(plan.entry_param_shape(1).unwrap().array().is_ok());
        assert!(plan.entry_param_shape(2).is_none());
    }
}
