//! Pure array operations for the HLO evaluator (everything that does
//! not need to apply a sub-computation). All index math works on
//! logical row-major layouts; every loop iterates output positions in
//! ascending flat order, so results are bit-deterministic regardless of
//! platform or thread count (see DESIGN.md §4).
//!
//! The `*_inplace` variants at the bottom are the planned executor's
//! buffer-reuse kernels: they share the exact per-element scalar
//! helpers with the allocating versions, so an in-place step is
//! bit-identical to its allocating twin by construction.

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::runtime::interp::parser::{
    BinaryOp, CmpDir, ConvDims, DotDims, GatherDims, ScatterDims, UnaryOp, WindowDim,
};
use crate::runtime::interp::value::{strides_of, unflatten, ArrayValue, Buf, ElemType};

// -------------------------------------------------------- elementwise ---

/// The f32 unary scalar kernel, shared by the allocating, in-place and
/// chained paths — one definition, so all three are bit-identical per
/// element by construction.
pub(crate) fn f32_unary(op: UnaryOp, v: f32) -> f32 {
    match op {
        UnaryOp::Negate => -v,
        UnaryOp::Exp => v.exp(),
        UnaryOp::Log => v.ln(),
        UnaryOp::Rsqrt => 1.0 / v.sqrt(),
        UnaryOp::Sine => v.sin(),
        UnaryOp::Cosine => v.cos(),
        UnaryOp::RoundNearestEven => v.round_ties_even(),
    }
}

pub fn unary(op: UnaryOp, a: &ArrayValue) -> Result<ArrayValue> {
    let buf = match (&*a.buf, op) {
        (Buf::S32(x), UnaryOp::Negate) => Buf::S32(x.iter().map(|&v| v.wrapping_neg()).collect()),
        (Buf::F32(x), _) => Buf::F32(x.iter().map(|&v| f32_unary(op, v)).collect()),
        (b, o) => bail!("unary {o:?} unsupported for {}", b.ty().name()),
    };
    Ok(ArrayValue { dims: a.dims.clone(), buf: Arc::new(buf) })
}

/// NaN-propagating max/min (XLA semantics; `f32::max` would drop NaN).
pub(crate) fn fmax(a: f32, b: f32) -> f32 {
    if a.is_nan() || b.is_nan() {
        f32::NAN
    } else if a >= b {
        a
    } else {
        b
    }
}

pub(crate) fn fmin(a: f32, b: f32) -> f32 {
    if a.is_nan() || b.is_nan() {
        f32::NAN
    } else if a <= b {
        a
    } else {
        b
    }
}

pub(crate) fn f32_bin(op: BinaryOp, a: f32, b: f32) -> Result<f32> {
    Ok(match op {
        BinaryOp::Add => a + b,
        BinaryOp::Sub => a - b,
        BinaryOp::Mul => a * b,
        BinaryOp::Div => a / b,
        BinaryOp::Max => fmax(a, b),
        BinaryOp::Min => fmin(a, b),
        BinaryOp::Pow => a.powf(b),
        other => bail!("binary {other:?} unsupported for f32"),
    })
}

pub(crate) fn u32_bin(op: BinaryOp, a: u32, b: u32) -> Result<u32> {
    Ok(match op {
        BinaryOp::Add => a.wrapping_add(b),
        BinaryOp::Sub => a.wrapping_sub(b),
        BinaryOp::Mul => a.wrapping_mul(b),
        BinaryOp::Div => {
            if b == 0 {
                0
            } else {
                a / b
            }
        }
        BinaryOp::Max => a.max(b),
        BinaryOp::Min => a.min(b),
        BinaryOp::And => a & b,
        BinaryOp::Or => a | b,
        BinaryOp::Xor => a ^ b,
        // XLA: logical shifts by >= bit width produce 0
        BinaryOp::Shl => {
            if b >= 32 {
                0
            } else {
                a << b
            }
        }
        BinaryOp::ShrLogical => {
            if b >= 32 {
                0
            } else {
                a >> b
            }
        }
        BinaryOp::Pow => bail!("binary Pow unsupported for u32"),
    })
}

pub(crate) fn s32_bin(op: BinaryOp, a: i32, b: i32) -> Result<i32> {
    Ok(match op {
        BinaryOp::Add => a.wrapping_add(b),
        BinaryOp::Sub => a.wrapping_sub(b),
        BinaryOp::Mul => a.wrapping_mul(b),
        BinaryOp::Div => {
            if b == 0 {
                0
            } else {
                a.wrapping_div(b)
            }
        }
        BinaryOp::Max => a.max(b),
        BinaryOp::Min => a.min(b),
        BinaryOp::And => a & b,
        BinaryOp::Or => a | b,
        BinaryOp::Xor => a ^ b,
        BinaryOp::Shl => {
            if !(0..32).contains(&b) {
                0
            } else {
                a.wrapping_shl(b as u32)
            }
        }
        BinaryOp::ShrLogical => {
            if !(0..32).contains(&b) {
                0
            } else {
                ((a as u32) >> b as u32) as i32
            }
        }
        BinaryOp::Pow => bail!("binary Pow unsupported for s32"),
    })
}

pub(crate) fn pred_bin(op: BinaryOp) -> Result<fn(bool, bool) -> bool> {
    Ok(match op {
        BinaryOp::And => |p, q| p & q,
        BinaryOp::Or => |p, q| p | q,
        BinaryOp::Xor => |p, q| p ^ q,
        other => bail!("binary {other:?} unsupported for pred"),
    })
}

pub fn binary(op: BinaryOp, a: &ArrayValue, b: &ArrayValue) -> Result<ArrayValue> {
    ensure!(
        a.dims == b.dims,
        "binary {op:?} shape mismatch {:?} vs {:?} (HLO has no implicit broadcast)",
        a.dims,
        b.dims
    );
    let buf = match (&*a.buf, &*b.buf) {
        (Buf::F32(x), Buf::F32(y)) => Buf::F32(
            x.iter().zip(y).map(|(&p, &q)| f32_bin(op, p, q)).collect::<Result<_>>()?,
        ),
        (Buf::U32(x), Buf::U32(y)) => Buf::U32(
            x.iter().zip(y).map(|(&p, &q)| u32_bin(op, p, q)).collect::<Result<_>>()?,
        ),
        (Buf::S32(x), Buf::S32(y)) => Buf::S32(
            x.iter().zip(y).map(|(&p, &q)| s32_bin(op, p, q)).collect::<Result<_>>()?,
        ),
        (Buf::Pred(x), Buf::Pred(y)) => {
            let f = pred_bin(op)?;
            Buf::Pred(x.iter().zip(y).map(|(&p, &q)| f(p, q)).collect())
        }
        _ => bail!("binary {op:?} operand type mismatch"),
    };
    Ok(ArrayValue { dims: a.dims.clone(), buf: Arc::new(buf) })
}

/// The compare scalar kernel, shared by [`compare`] and the chained
/// path (same comparison expressions, so both are bit-identical).
pub(crate) fn cmp_elem<T: PartialOrd + PartialEq>(dir: CmpDir, p: T, q: T) -> bool {
    match dir {
        CmpDir::Eq => p == q,
        CmpDir::Ne => p != q,
        CmpDir::Lt => p < q,
        CmpDir::Le => p <= q,
        CmpDir::Gt => p > q,
        CmpDir::Ge => p >= q,
    }
}

pub fn compare(dir: CmpDir, a: &ArrayValue, b: &ArrayValue) -> Result<ArrayValue> {
    ensure!(a.dims == b.dims, "compare shape mismatch");
    fn cmp<T: PartialOrd + PartialEq + Copy>(dir: CmpDir, x: &[T], y: &[T]) -> Vec<bool> {
        x.iter().zip(y).map(|(&p, &q)| cmp_elem(dir, p, q)).collect()
    }
    let out = match (&*a.buf, &*b.buf) {
        (Buf::F32(x), Buf::F32(y)) => cmp(dir, x, y),
        (Buf::S32(x), Buf::S32(y)) => cmp(dir, x, y),
        (Buf::U32(x), Buf::U32(y)) => cmp(dir, x, y),
        (Buf::Pred(x), Buf::Pred(y)) => cmp(dir, x, y),
        _ => bail!("compare operand type mismatch"),
    };
    Ok(ArrayValue { dims: a.dims.clone(), buf: Arc::new(Buf::Pred(out)) })
}

pub fn select(p: &ArrayValue, t: &ArrayValue, f: &ArrayValue) -> Result<ArrayValue> {
    ensure!(p.dims == t.dims && t.dims == f.dims, "select shape mismatch");
    ensure!(t.ty() == f.ty(), "select branch type mismatch");
    let pred = p.as_pred()?;
    let mut buf = Buf::with_capacity(t.ty(), t.numel());
    for (i, &take_t) in pred.iter().enumerate() {
        buf.push_from(if take_t { &t.buf } else { &f.buf }, i);
    }
    Ok(ArrayValue { dims: t.dims.clone(), buf: Arc::new(buf) })
}

pub fn convert(a: &ArrayValue, to: ElemType) -> Result<ArrayValue> {
    let buf = match (&*a.buf, to) {
        (Buf::F32(x), ElemType::F32) => Buf::F32(x.clone()),
        (Buf::F32(x), ElemType::S32) => Buf::S32(x.iter().map(|&v| v as i32).collect()),
        (Buf::F32(x), ElemType::U32) => Buf::U32(x.iter().map(|&v| v as u32).collect()),
        (Buf::F32(x), ElemType::Pred) => Buf::Pred(x.iter().map(|&v| v != 0.0).collect()),
        (Buf::S32(x), ElemType::F32) => Buf::F32(x.iter().map(|&v| v as f32).collect()),
        (Buf::S32(x), ElemType::S32) => Buf::S32(x.clone()),
        (Buf::S32(x), ElemType::U32) => Buf::U32(x.iter().map(|&v| v as u32).collect()),
        (Buf::S32(x), ElemType::Pred) => Buf::Pred(x.iter().map(|&v| v != 0).collect()),
        (Buf::U32(x), ElemType::F32) => Buf::F32(x.iter().map(|&v| v as f32).collect()),
        (Buf::U32(x), ElemType::S32) => Buf::S32(x.iter().map(|&v| v as i32).collect()),
        (Buf::U32(x), ElemType::U32) => Buf::U32(x.clone()),
        (Buf::U32(x), ElemType::Pred) => Buf::Pred(x.iter().map(|&v| v != 0).collect()),
        (Buf::Pred(x), ElemType::F32) => {
            Buf::F32(x.iter().map(|&v| if v { 1.0 } else { 0.0 }).collect())
        }
        (Buf::Pred(x), ElemType::S32) => {
            Buf::S32(x.iter().map(|&v| if v { 1 } else { 0 }).collect())
        }
        (Buf::Pred(x), ElemType::U32) => {
            Buf::U32(x.iter().map(|&v| if v { 1 } else { 0 }).collect())
        }
        (Buf::Pred(x), ElemType::Pred) => Buf::Pred(x.clone()),
    };
    Ok(ArrayValue { dims: a.dims.clone(), buf: Arc::new(buf) })
}

pub fn bitcast_convert(a: &ArrayValue, to: ElemType) -> Result<ArrayValue> {
    let buf = match (&*a.buf, to) {
        (Buf::F32(x), ElemType::U32) => Buf::U32(x.iter().map(|&v| v.to_bits()).collect()),
        (Buf::F32(x), ElemType::S32) => Buf::S32(x.iter().map(|&v| v.to_bits() as i32).collect()),
        (Buf::U32(x), ElemType::F32) => Buf::F32(x.iter().map(|&v| f32::from_bits(v)).collect()),
        (Buf::S32(x), ElemType::F32) => {
            Buf::F32(x.iter().map(|&v| f32::from_bits(v as u32)).collect())
        }
        (Buf::U32(x), ElemType::S32) => Buf::S32(x.iter().map(|&v| v as i32).collect()),
        (Buf::S32(x), ElemType::U32) => Buf::U32(x.iter().map(|&v| v as u32).collect()),
        (b, t) if b.ty() == t => b.clone(),
        (b, t) => bail!("bitcast-convert {} -> {} unsupported", b.ty().name(), t.name()),
    };
    Ok(ArrayValue { dims: a.dims.clone(), buf: Arc::new(buf) })
}

// ------------------------------------------------- elementwise chains ---

/// One op of a compiled elementwise-chain tape (DESIGN.md §4). A chain
/// superinstruction evaluates its whole tape once per output element
/// over a scratch of raw 32-bit slot payloads (f32 bit patterns,
/// s32/u32 bit patterns, pred as 0/1): slots `0..n_inputs` hold the
/// chain's external inputs for that element, op `t` writes slot
/// `n_inputs + t`, and the last op's slot is the element's value.
/// Every op decodes its statically-typed operands and applies the
/// *same scalar helpers* as the standalone kernels ([`f32_unary`],
/// [`f32_bin`], [`s32_bin`], [`u32_bin`], [`pred_bin`], [`cmp_elem`],
/// [`convert`]'s per-element rules), so a chained element is
/// bit-identical to the unfused instruction sequence by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TapeOp {
    Unary { op: UnaryOp, ty: ElemType, a: u16 },
    Binary { op: BinaryOp, ty: ElemType, a: u16, b: u16 },
    /// `ty` is the *operand* type; the result is a pred payload.
    Compare { dir: CmpDir, ty: ElemType, a: u16, b: u16 },
    /// Raw payload pass-through of `t` or `f` — type-agnostic, exactly
    /// like [`select`]'s untyped element copy.
    Select { p: u16, t: u16, f: u16 },
    Convert { from: ElemType, to: ElemType, a: u16 },
}

/// One chain input as the per-element loop sees it.
#[derive(Clone, Copy)]
pub enum LaneRef<'a> {
    F32(&'a [f32]),
    S32(&'a [i32]),
    U32(&'a [u32]),
    Pred(&'a [bool]),
    /// A broadcast-of-scalar folded into the chain: the same payload
    /// for every element.
    Splat(u32),
    /// The in-place destination's previous value, read from the chunk
    /// element about to be overwritten.
    Dst,
}

impl LaneRef<'_> {
    #[inline]
    fn load(&self, i: usize, cur: u32) -> u32 {
        match *self {
            LaneRef::F32(xs) => xs[i].to_bits(),
            LaneRef::S32(xs) => xs[i] as u32,
            LaneRef::U32(xs) => xs[i],
            LaneRef::Pred(xs) => xs[i] as u32,
            LaneRef::Splat(v) => v,
            LaneRef::Dst => cur,
        }
    }
}

/// Per-element [`convert`] on a raw payload — the same cast
/// expressions as the allocating kernel, arm for arm.
fn convert_scalar(from: ElemType, to: ElemType, v: u32) -> u32 {
    use ElemType::{Pred, F32, S32, U32};
    match (from, to) {
        (F32, S32) => (f32::from_bits(v) as i32) as u32,
        (F32, U32) => f32::from_bits(v) as u32,
        (F32, Pred) => (f32::from_bits(v) != 0.0) as u32,
        (S32, F32) => ((v as i32) as f32).to_bits(),
        (U32, F32) => (v as f32).to_bits(),
        (Pred, F32) => (if v != 0 { 1.0f32 } else { 0.0 }).to_bits(),
        // int -> pred normalizes the payload to 0/1 (pred payloads are
        // always canonical, so pred -> int is the payload itself)
        (S32 | U32, Pred) => (v != 0) as u32,
        // s32 <-> u32 are `as` casts (bit pattern) and same-type
        // converts are copies
        (S32 | U32, S32 | U32) | (Pred, S32 | U32 | Pred) | (F32, F32) => v,
    }
}

/// Evaluate one tape op against the slot scratch. Payloads decode per
/// the op's static types; every arithmetic path is one of the shared
/// scalar helpers, so the tape cannot diverge from the standalone
/// kernels.
fn tape_step(op: &TapeOp, slots: &[u32]) -> Result<u32> {
    let s = |i: u16| slots[i as usize];
    Ok(match *op {
        TapeOp::Unary { op, ty, a } => match ty {
            ElemType::F32 => f32_unary(op, f32::from_bits(s(a))).to_bits(),
            ElemType::S32 if op == UnaryOp::Negate => (s(a) as i32).wrapping_neg() as u32,
            _ => bail!("unary {op:?} unsupported for {}", ty.name()),
        },
        TapeOp::Binary { op, ty, a, b } => match ty {
            ElemType::F32 => f32_bin(op, f32::from_bits(s(a)), f32::from_bits(s(b)))?.to_bits(),
            ElemType::S32 => s32_bin(op, s(a) as i32, s(b) as i32)? as u32,
            ElemType::U32 => u32_bin(op, s(a), s(b))?,
            ElemType::Pred => pred_bin(op)?(s(a) != 0, s(b) != 0) as u32,
        },
        TapeOp::Compare { dir, ty, a, b } => (match ty {
            ElemType::F32 => cmp_elem(dir, f32::from_bits(s(a)), f32::from_bits(s(b))),
            ElemType::S32 => cmp_elem(dir, s(a) as i32, s(b) as i32),
            ElemType::U32 => cmp_elem(dir, s(a), s(b)),
            ElemType::Pred => cmp_elem(dir, s(a) != 0, s(b) != 0),
        }) as u32,
        TapeOp::Select { p, t, f } => {
            if s(p) != 0 {
                s(t)
            } else {
                s(f)
            }
        }
        TapeOp::Convert { from, to, a } => convert_scalar(from, to, s(a)),
    })
}

/// Execute a compiled chain tape over every output element: fill the
/// input slots from `lanes`, run the tape, write the last slot into
/// `dst`. [`LaneRef::Dst`] lanes read the destination element's
/// previous value before it is overwritten, which makes in-place
/// execution safe — each element's loads complete before its store and
/// no element reads another element's storage. Sharded across
/// `workers` above [`ELEM_PAR_MIN`] elements; per-element work is
/// independent, so the split is bit-identical at any worker count.
pub fn chain_apply(
    tape: &[TapeOp],
    lanes: &[LaneRef],
    dst: &mut Buf,
    workers: usize,
) -> Result<()> {
    ensure!(!tape.is_empty(), "empty chain tape");
    fn run<T: Send + Copy>(
        tape: &[TapeOp],
        lanes: &[LaneRef],
        w: usize,
        xs: &mut [T],
        enc: impl Fn(T) -> u32 + Sync,
        dec: impl Fn(u32) -> T + Sync,
    ) -> Result<()> {
        let n_in = lanes.len();
        shard_mut(xs, w, |off, c| {
            let mut slots = vec![0u32; n_in + tape.len()];
            for (i, o) in c.iter_mut().enumerate() {
                let cur = enc(*o);
                for (k, lane) in lanes.iter().enumerate() {
                    slots[k] = lane.load(off + i, cur);
                }
                for (t, op) in tape.iter().enumerate() {
                    slots[n_in + t] = tape_step(op, &slots)?;
                }
                *o = dec(slots[n_in + tape.len() - 1]);
            }
            Ok(())
        })
    }
    let w = if dst.len() >= ELEM_PAR_MIN { workers } else { 1 };
    match dst {
        Buf::F32(xs) => run(tape, lanes, w, xs, f32::to_bits, f32::from_bits),
        Buf::S32(xs) => run(tape, lanes, w, xs, |v| v as u32, |r| r as i32),
        Buf::U32(xs) => run(tape, lanes, w, xs, |v| v, |r| r),
        Buf::Pred(xs) => run(tape, lanes, w, xs, |v| v as u32, |r| r != 0),
    }
}

// ---------------------------------------------------- in-place kernels ---

/// Element count below which intra-op sharding of elementwise /
/// threefry / fused-reduce kernels is never worth the spawn overhead
/// (the packed dot keeps its own `DOT_PAR_MIN` with the same value).
pub const ELEM_PAR_MIN: usize = 4096;

/// Run `f` over contiguous chunks of `xs` on up to `workers` scoped
/// threads. Each element is written by exactly one worker with the same
/// scalar kernel it would see serially, so the result is bit-identical
/// at any worker count; errors propagate (first chunk's error wins).
fn shard_mut<T: Send>(
    xs: &mut [T],
    workers: usize,
    f: impl Fn(usize, &mut [T]) -> Result<()> + Sync,
) -> Result<()> {
    let w = workers.min(xs.len()).max(1);
    if w <= 1 {
        return f(0, xs);
    }
    let chunk = xs.len().div_ceil(w);
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = xs
            .chunks_mut(chunk)
            .enumerate()
            .map(|(ci, c)| s.spawn(move || f(ci * chunk, c)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("elementwise worker panicked"))
            .collect::<Result<()>>()
    })
}

fn unary_f32_slice(op: UnaryOp, x: &mut [f32]) -> Result<()> {
    x.iter_mut().for_each(|v| *v = f32_unary(op, *v));
    Ok(())
}

/// [`unary`] with the result written back into `a`'s storage.
pub fn unary_inplace(op: UnaryOp, a: &mut Buf) -> Result<()> {
    unary_inplace_sharded(op, a, 1)
}

/// [`unary_inplace`] sharded across `workers` above [`ELEM_PAR_MIN`]
/// elements (bit-identical at any worker count).
pub fn unary_inplace_sharded(op: UnaryOp, a: &mut Buf, workers: usize) -> Result<()> {
    let w = if a.len() >= ELEM_PAR_MIN { workers } else { 1 };
    match (a, op) {
        (Buf::F32(x), _) => shard_mut(x, w, |_, c| unary_f32_slice(op, c)),
        (Buf::S32(x), UnaryOp::Negate) => {
            shard_mut(x, w, |_, c| {
                c.iter_mut().for_each(|v| *v = v.wrapping_neg());
                Ok(())
            })
        }
        (b, o) => bail!("unary {o:?} unsupported for {}", b.ty().name()),
    }
}

/// Apply `step(lhs, rhs)` in place over a dst/src chunk pair. `step`'s
/// (lhs, rhs) value order matches [`binary`] exactly.
fn bin_slice<T: Copy>(
    dst_is_lhs: bool,
    d: &mut [T],
    o: &[T],
    step: impl Fn(T, T) -> Result<T>,
) -> Result<()> {
    if dst_is_lhs {
        for (x, &y) in d.iter_mut().zip(o) {
            *x = step(*x, y)?;
        }
    } else {
        for (x, &y) in d.iter_mut().zip(o) {
            *x = step(y, *x)?;
        }
    }
    Ok(())
}

/// [`binary`] with the result written into one operand's buffer.
/// `dst_is_lhs` says which operand `dst` holds; the (lhs, rhs) value
/// order — and hence every rounding — matches [`binary`] exactly.
pub fn binary_inplace(op: BinaryOp, dst_is_lhs: bool, dst: &mut Buf, other: &Buf) -> Result<()> {
    binary_inplace_sharded(op, dst_is_lhs, dst, other, 1)
}

/// [`binary_inplace`] sharded across `workers` above [`ELEM_PAR_MIN`]
/// elements (bit-identical at any worker count).
pub fn binary_inplace_sharded(
    op: BinaryOp,
    dst_is_lhs: bool,
    dst: &mut Buf,
    other: &Buf,
    workers: usize,
) -> Result<()> {
    ensure!(dst.len() == other.len(), "binary {op:?} length mismatch");
    let w = if dst.len() >= ELEM_PAR_MIN { workers } else { 1 };
    match (dst, other) {
        (Buf::F32(d), Buf::F32(o)) => {
            shard_mut(d, w, |lo, c| {
                bin_slice(dst_is_lhs, c, &o[lo..lo + c.len()], |a, b| f32_bin(op, a, b))
            })
        }
        (Buf::U32(d), Buf::U32(o)) => {
            shard_mut(d, w, |lo, c| {
                bin_slice(dst_is_lhs, c, &o[lo..lo + c.len()], |a, b| u32_bin(op, a, b))
            })
        }
        (Buf::S32(d), Buf::S32(o)) => {
            shard_mut(d, w, |lo, c| {
                bin_slice(dst_is_lhs, c, &o[lo..lo + c.len()], |a, b| s32_bin(op, a, b))
            })
        }
        (Buf::Pred(d), Buf::Pred(o)) => {
            let f = pred_bin(op)?;
            shard_mut(d, w, |lo, c| {
                bin_slice(dst_is_lhs, c, &o[lo..lo + c.len()], |a, b| Ok(f(a, b)))
            })
        }
        _ => bail!("binary {op:?} operand type mismatch"),
    }
}

fn select_slice<T: Copy>(pred: &[bool], dst_is_true: bool, d: &mut [T], o: &[T]) {
    for (i, &take_t) in pred.iter().enumerate() {
        if take_t != dst_is_true {
            d[i] = o[i];
        }
    }
}

/// [`select`] with the result written into one branch's buffer
/// (`dst_is_true`: `dst` holds the on-true values).
pub fn select_inplace(pred: &[bool], dst_is_true: bool, dst: &mut Buf, other: &Buf) -> Result<()> {
    select_inplace_sharded(pred, dst_is_true, dst, other, 1)
}

/// [`select_inplace`] sharded across `workers` above [`ELEM_PAR_MIN`]
/// elements (bit-identical at any worker count).
pub fn select_inplace_sharded(
    pred: &[bool],
    dst_is_true: bool,
    dst: &mut Buf,
    other: &Buf,
    workers: usize,
) -> Result<()> {
    ensure!(pred.len() == dst.len() && dst.len() == other.len(), "select shape mismatch");
    ensure!(dst.ty() == other.ty(), "select branch type mismatch");
    let w = if dst.len() >= ELEM_PAR_MIN { workers } else { 1 };
    match (dst, other) {
        (Buf::F32(d), Buf::F32(o)) => shard_mut(d, w, |lo, c| {
            select_slice(&pred[lo..lo + c.len()], dst_is_true, c, &o[lo..lo + c.len()]);
            Ok(())
        }),
        (Buf::S32(d), Buf::S32(o)) => shard_mut(d, w, |lo, c| {
            select_slice(&pred[lo..lo + c.len()], dst_is_true, c, &o[lo..lo + c.len()]);
            Ok(())
        }),
        (Buf::U32(d), Buf::U32(o)) => shard_mut(d, w, |lo, c| {
            select_slice(&pred[lo..lo + c.len()], dst_is_true, c, &o[lo..lo + c.len()]);
            Ok(())
        }),
        (Buf::Pred(d), Buf::Pred(o)) => shard_mut(d, w, |lo, c| {
            select_slice(&pred[lo..lo + c.len()], dst_is_true, c, &o[lo..lo + c.len()]);
            Ok(())
        }),
        _ => bail!("select branch type mismatch"),
    }
}

// ------------------------------------------------------------ threefry ---

/// Rotate-left as the HLO round body composes it:
/// `shl(v, r) | shr(v, 32 - r)` under XLA shift semantics (a shift
/// amount ≥ 32 yields 0, and `32 - r` wraps as u32) — exact for every
/// `r`, including 0 and ≥ 32.
#[inline]
pub(crate) fn rotl_xla(v: u32, r: u32) -> u32 {
    let shl = if r >= 32 { 0 } else { v << r };
    let s = 32u32.wrapping_sub(r);
    let shr = if s >= 32 { 0 } else { v >> s };
    shl | shr
}

fn threefry_sweep(x0: &mut [u32], x1: &mut [u32], rot: &[u32; 4], k0: u32, k1: u32) {
    for (a, b) in x0.iter_mut().zip(x1.iter_mut()) {
        let (mut x, mut y) = (*a, *b);
        for &r in rot {
            x = x.wrapping_add(y);
            y = x ^ rotl_xla(y, r);
        }
        *a = x.wrapping_add(k0);
        *b = y.wrapping_add(k1);
    }
}

/// Native threefry-2x32 round group: four add/xor/rotate rounds then
/// key injection, swept over all lanes in one unrolled pass. Exact u32
/// wrapping arithmetic — bit-identical to the generic elementwise
/// chain it replaces (validated against the reference mirror on the
/// committed fixture, `tools/qnsim/plan_mirror.py`). `k1` already
/// carries the round-index injection (`key + (i+1)`): u32 addition is
/// associative, so folding it in is exact. Lanes shard across scoped
/// workers above [`ELEM_PAR_MIN`]; each lane is independent, so the
/// result is bit-identical at any worker count.
///
/// Keep in sync: this kernel, `fuse::expected_round` (the planner's
/// matcher) and `verify::round_chain` (the static verifier's
/// independent re-proof) all encode the same jax threefry lowering —
/// the sharding here is declared per-element in
/// [`crate::runtime::interp::verify::SHARD_REGISTRY`] (DESIGN.md §8).
pub fn threefry2x32(
    x0: &mut [u32],
    x1: &mut [u32],
    rot: &[u32; 4],
    k0: u32,
    k1: u32,
    workers: usize,
) -> Result<()> {
    ensure!(x0.len() == x1.len(), "threefry lane count mismatch");
    let w = if x0.len() >= ELEM_PAR_MIN { workers.min(x0.len()).max(1) } else { 1 };
    if w <= 1 {
        threefry_sweep(x0, x1, rot, k0, k1);
    } else {
        let chunk = x0.len().div_ceil(w);
        std::thread::scope(|s| {
            for (ca, cb) in x0.chunks_mut(chunk).zip(x1.chunks_mut(chunk)) {
                s.spawn(move || threefry_sweep(ca, cb, rot, k0, k1));
            }
        });
    }
    Ok(())
}

// ---------------------------------------------------------- shape ops ---

pub fn iota(ty: ElemType, dims: &[usize], dim: usize) -> Result<ArrayValue> {
    ensure!(dim < dims.len(), "iota dimension {dim} out of range for {dims:?}");
    let st = strides_of(dims);
    let n: usize = dims.iter().product();
    let coord = |f: usize| (f / st[dim]) % dims[dim];
    let buf = match ty {
        ElemType::F32 => Buf::F32((0..n).map(|f| coord(f) as f32).collect()),
        ElemType::S32 => Buf::S32((0..n).map(|f| coord(f) as i32).collect()),
        ElemType::U32 => Buf::U32((0..n).map(|f| coord(f) as u32).collect()),
        ElemType::Pred => bail!("iota of pred unsupported"),
    };
    Ok(ArrayValue { dims: dims.to_vec(), buf: Arc::new(buf) })
}

/// `dimensions[k]` names the output dimension that operand dimension
/// `k` maps to; all other output dimensions replicate.
pub fn broadcast(a: &ArrayValue, out_dims: &[usize], mapping: &[usize]) -> Result<ArrayValue> {
    ensure!(mapping.len() == a.dims.len(), "broadcast mapping rank mismatch");
    let n: usize = out_dims.iter().product();
    // scalar splat: every output cell replicates the one element
    if a.dims.is_empty() && n > 0 {
        return Ok(ArrayValue { dims: out_dims.to_vec(), buf: Arc::new(a.buf.splat(0, n)) });
    }
    let xst = strides_of(&a.dims);
    let ost = strides_of(out_dims);
    let mut oi = vec![0usize; out_dims.len()];
    let mut buf = Buf::with_capacity(a.ty(), n);
    for f in 0..n {
        unflatten(f, &ost, &mut oi);
        let mut xi = 0;
        for (k, &d) in mapping.iter().enumerate() {
            xi += oi[d] * xst[k];
        }
        buf.push_from(&a.buf, xi);
    }
    Ok(ArrayValue { dims: out_dims.to_vec(), buf: Arc::new(buf) })
}

pub fn transpose(a: &ArrayValue, perm: &[usize]) -> Result<ArrayValue> {
    ensure!(perm.len() == a.dims.len(), "transpose permutation rank mismatch");
    let out_dims: Vec<usize> = perm.iter().map(|&p| a.dims[p]).collect();
    let xst = strides_of(&a.dims);
    let ost = strides_of(&out_dims);
    let n = a.numel();
    let mut oi = vec![0usize; out_dims.len()];
    let mut buf = Buf::with_capacity(a.ty(), n);
    for f in 0..n {
        unflatten(f, &ost, &mut oi);
        let mut xi = 0;
        for (d, &p) in perm.iter().enumerate() {
            xi += oi[d] * xst[p];
        }
        buf.push_from(&a.buf, xi);
    }
    Ok(ArrayValue { dims: out_dims, buf: Arc::new(buf) })
}

pub fn slice(a: &ArrayValue, spec: &[(usize, usize, usize)]) -> Result<ArrayValue> {
    ensure!(spec.len() == a.dims.len(), "slice rank mismatch");
    let out_dims: Vec<usize> = spec
        .iter()
        .map(|&(s, l, st)| {
            ensure!(st > 0 && s <= l, "bad slice bounds [{s}:{l}:{st}]");
            Ok((l - s).div_ceil(st))
        })
        .collect::<Result<_>>()?;
    let xst = strides_of(&a.dims);
    let ost = strides_of(&out_dims);
    let n: usize = out_dims.iter().product();
    let mut oi = vec![0usize; out_dims.len()];
    let mut buf = Buf::with_capacity(a.ty(), n);
    for f in 0..n {
        unflatten(f, &ost, &mut oi);
        let mut xi = 0;
        for (d, &(s, _, st)) in spec.iter().enumerate() {
            xi += (s + oi[d] * st) * xst[d];
        }
        buf.push_from(&a.buf, xi);
    }
    Ok(ArrayValue { dims: out_dims, buf: Arc::new(buf) })
}

pub fn concatenate(parts: &[&ArrayValue], dim: usize) -> Result<ArrayValue> {
    ensure!(!parts.is_empty(), "concatenate of nothing");
    let first = parts[0];
    ensure!(dim < first.dims.len(), "concatenate dim out of range");
    let mut out_dims = first.dims.clone();
    out_dims[dim] = parts.iter().map(|p| p.dims[dim]).sum();
    // view every operand as [outer, k_p, inner] and copy contiguous runs
    let outer: usize = first.dims[..dim].iter().product();
    let inner: usize = first.dims[dim + 1..].iter().product();
    let n: usize = out_dims.iter().product();
    let mut buf = Buf::with_capacity(first.ty(), n);
    for o in 0..outer {
        for p in parts {
            ensure!(p.ty() == first.ty(), "concatenate type mismatch");
            let run = p.dims[dim] * inner;
            for i in 0..run {
                buf.push_from(&p.buf, o * run + i);
            }
        }
    }
    Ok(ArrayValue { dims: out_dims, buf: Arc::new(buf) })
}

// ----------------------------------------------------------------- dot ---

/// General dot product: output dims are (batch…, lhs free…, rhs free…).
/// f32 only (the artifacts never lower integer dots); accumulates in
/// f32 like XLA's CPU backend.
///
/// This is the reference formulation (one flat output loop, index math
/// per contraction element). Each output element accumulates with four
/// stride-4 partial sums over ascending contraction index, combined as
/// `(s0+s1)+(s2+s3)`, then a sequential tail — the same operation order
/// as [`crate::quant::assign::dot`]. The planned executor's blocked dot
/// ([`crate::runtime::interp::plan`]) reproduces this order per output
/// lane and must match it bit-for-bit.
pub fn dot(lhs: &ArrayValue, rhs: &ArrayValue, nums: &DotDims) -> Result<ArrayValue> {
    let x = lhs.as_f32()?;
    let y = rhs.as_f32()?;
    let lfree: Vec<usize> = (0..lhs.dims.len())
        .filter(|d| !nums.lhs_batch.contains(d) && !nums.lhs_contracting.contains(d))
        .collect();
    let rfree: Vec<usize> = (0..rhs.dims.len())
        .filter(|d| !nums.rhs_batch.contains(d) && !nums.rhs_contracting.contains(d))
        .collect();
    let mut out_dims: Vec<usize> = nums.lhs_batch.iter().map(|&d| lhs.dims[d]).collect();
    out_dims.extend(lfree.iter().map(|&d| lhs.dims[d]));
    out_dims.extend(rfree.iter().map(|&d| rhs.dims[d]));

    let lst = strides_of(&lhs.dims);
    let rst = strides_of(&rhs.dims);
    let ost = strides_of(&out_dims);
    let kdims: Vec<usize> = nums.lhs_contracting.iter().map(|&d| lhs.dims[d]).collect();
    for (i, &d) in nums.rhs_contracting.iter().enumerate() {
        ensure!(rhs.dims[d] == kdims[i], "dot contracting dim mismatch");
    }
    let kst = strides_of(&kdims);
    let kn: usize = kdims.iter().product();
    let n: usize = out_dims.iter().product();
    let nb = nums.lhs_batch.len();
    let nlf = lfree.len();
    let mut oi = vec![0usize; out_dims.len()];
    let mut ki = vec![0usize; kdims.len()];
    let mut out = Vec::with_capacity(n);
    for f in 0..n {
        unflatten(f, &ost, &mut oi);
        let mut lbase = 0;
        let mut rbase = 0;
        for k in 0..nb {
            lbase += oi[k] * lst[nums.lhs_batch[k]];
            rbase += oi[k] * rst[nums.rhs_batch[k]];
        }
        for (k, &d) in lfree.iter().enumerate() {
            lbase += oi[nb + k] * lst[d];
        }
        for (k, &d) in rfree.iter().enumerate() {
            rbase += oi[nb + nlf + k] * rst[d];
        }
        let mut term = |kf: usize, ki: &mut Vec<usize>| -> f32 {
            unflatten(kf, &kst, ki);
            let mut li = lbase;
            let mut ri = rbase;
            for (t, &kc) in ki.iter().enumerate() {
                li += kc * lst[nums.lhs_contracting[t]];
                ri += kc * rst[nums.rhs_contracting[t]];
            }
            x[li] * y[ri]
        };
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        let kn4 = kn - kn % 4;
        let mut kf = 0;
        while kf < kn4 {
            s0 += term(kf, &mut ki);
            s1 += term(kf + 1, &mut ki);
            s2 += term(kf + 2, &mut ki);
            s3 += term(kf + 3, &mut ki);
            kf += 4;
        }
        let mut acc = (s0 + s1) + (s2 + s3);
        while kf < kn {
            acc += term(kf, &mut ki);
            kf += 1;
        }
        out.push(acc);
    }
    Ok(ArrayValue { dims: out_dims, buf: Arc::new(Buf::F32(out)) })
}

// -------------------------------------------------------------- gather ---

/// StableHLO gather, including the batching dims jax 0.4.3x emits for
/// vmapped `take_along_axis`.
pub fn gather(
    operand: &ArrayValue,
    start: &ArrayValue,
    g: &GatherDims,
    out_dims: &[usize],
) -> Result<ArrayValue> {
    let orank = operand.dims.len();
    // start_indices dims excluding index_vector_dim, in order
    let sdims: Vec<usize> = (0..start.dims.len()).filter(|&d| d != g.index_vector_dim).collect();
    let batch_out: Vec<usize> =
        (0..out_dims.len()).filter(|d| !g.offset_dims.contains(d)).collect();
    let off_operand: Vec<usize> = (0..orank)
        .filter(|d| {
            !g.collapsed_slice_dims.contains(d) && !g.operand_batching_dims.contains(d)
        })
        .collect();
    ensure!(off_operand.len() == g.offset_dims.len(), "gather offset_dims arity mismatch");
    ensure!(g.slice_sizes.len() == orank, "gather slice_sizes arity mismatch");
    ensure!(batch_out.len() == sdims.len(), "gather batch rank mismatch");
    for (d, (&sz, &od)) in g.slice_sizes.iter().zip(&operand.dims).enumerate() {
        ensure!(sz <= od, "gather slice_sizes[{d}] = {sz} exceeds operand dim {od}");
    }

    let ost = strides_of(out_dims);
    let pst = strides_of(&operand.dims);
    let sst = strides_of(&start.dims);
    let n: usize = out_dims.iter().product();
    let mut oi = vec![0usize; out_dims.len()];
    let mut full = vec![0usize; orank];
    let mut buf = Buf::with_capacity(operand.ty(), n);
    for f in 0..n {
        unflatten(f, &ost, &mut oi);
        // flat position of this output cell's index vector (minus the
        // index_vector_dim component, added per start_index_map entry)
        let mut sbase = 0;
        for (j, &sd) in sdims.iter().enumerate() {
            sbase += oi[batch_out[j]] * sst[sd];
        }
        full.iter_mut().for_each(|v| *v = 0);
        for (k, &od) in g.start_index_map.iter().enumerate() {
            let si = if g.index_vector_dim < start.dims.len() {
                sbase + k * sst[g.index_vector_dim]
            } else {
                sbase
            };
            let idx = start.buf.index_at(si)?;
            let hi = (operand.dims[od] - g.slice_sizes[od]) as i64;
            full[od] = idx.clamp(0, hi) as usize;
        }
        for (&od, &sd) in g.operand_batching_dims.iter().zip(&g.start_indices_batching_dims) {
            let j = sdims.iter().position(|&x| x == sd).unwrap();
            full[od] = oi[batch_out[j]];
        }
        let mut pi: usize = full.iter().zip(&pst).map(|(&v, &s)| v * s).sum();
        for (k, &d) in off_operand.iter().enumerate() {
            pi += oi[g.offset_dims[k]] * pst[d];
        }
        buf.push_from(&operand.buf, pi);
    }
    Ok(ArrayValue { dims: out_dims.to_vec(), buf: Arc::new(buf) })
}

// -------------------------------------------------------------- reduce ---

/// Derived index geometry of a reduce over one input shape, shared by
/// every engine (tree-walk reference, fused and generic planned paths)
/// so the visit-order-defining math exists exactly once: output cells
/// ascend in flat order; within a cell, reduced elements ascend in
/// row-major order over the `dimensions` list.
pub(crate) struct ReduceGeom {
    /// input dims NOT reduced, ascending
    kept: Vec<usize>,
    /// the reduced dims, in attribute order
    dims: Vec<usize>,
    pub out_dims: Vec<usize>,
    /// reduced elements per output cell
    pub rn: usize,
    /// output cells
    pub n: usize,
    rank: usize,
    xst: Vec<usize>,
    ost: Vec<usize>,
    rst: Vec<usize>,
}

impl ReduceGeom {
    pub fn new(x_dims: &[usize], dims: &[usize]) -> ReduceGeom {
        let kept: Vec<usize> = (0..x_dims.len()).filter(|d| !dims.contains(d)).collect();
        let out_dims: Vec<usize> = kept.iter().map(|&d| x_dims[d]).collect();
        let red_dims: Vec<usize> = dims.iter().map(|&d| x_dims[d]).collect();
        ReduceGeom {
            xst: strides_of(x_dims),
            ost: strides_of(&out_dims),
            rst: strides_of(&red_dims),
            rn: red_dims.iter().product(),
            n: out_dims.iter().product(),
            rank: x_dims.len(),
            kept,
            dims: dims.to_vec(),
            out_dims,
        }
    }

    /// Scratch coordinate buffers for `cell_base` / `elem_index`.
    pub fn scratch(&self) -> (Vec<usize>, Vec<usize>) {
        (vec![0; self.out_dims.len()], vec![0; self.dims.len()])
    }

    /// Flat input base index of output cell `f`.
    pub fn cell_base(&self, f: usize, oi: &mut [usize]) -> usize {
        unflatten(f, &self.ost, oi);
        let mut base = 0;
        for (k, &d) in self.kept.iter().enumerate() {
            base += oi[k] * self.xst[d];
        }
        base
    }

    /// Flat input index of reduced element `rf` within a cell.
    pub fn elem_index(&self, base: usize, rf: usize, ri: &mut [usize]) -> usize {
        unflatten(rf, &self.rst, ri);
        let mut xi = base;
        for (k, &d) in self.dims.iter().enumerate() {
            xi += ri[k] * self.xst[d];
        }
        xi
    }

    /// Reduced dims are exactly the trailing input dims in ascending
    /// order ⇒ every cell folds one contiguous run `[f·rn, (f+1)·rn)`.
    pub fn contiguous(&self) -> bool {
        (0..self.dims.len()).all(|t| self.dims[t] == self.rank - self.dims.len() + t)
    }
}

/// Fold every output cell of a fused single-binary-op reduce: cell `f`
/// folds its `g.rn` reduced elements in ascending row-major order onto
/// `i0` with `step` — the identical visit order and scalar helper as
/// the generic region path, so the result is bit-identical to it.
/// Output cells shard across `workers` scoped threads above
/// [`ELEM_PAR_MIN`] total elements; each cell's fold is computed by
/// exactly one worker and chunks merge in ascending order, so the
/// result is also bit-identical at any worker count.
pub(crate) fn fold_cells<T: Copy + Send + Sync>(
    g: &ReduceGeom,
    xs: &[T],
    i0: T,
    step: impl Fn(T, T) -> Result<T> + Sync,
    workers: usize,
) -> Result<Vec<T>> {
    let contiguous = g.contiguous();
    let run = |lo: usize, out: &mut [T]| -> Result<()> {
        let (mut oi, mut ri) = g.scratch();
        for (k, slot) in out.iter_mut().enumerate() {
            let f = lo + k;
            let mut acc = i0;
            if contiguous {
                for &v in &xs[f * g.rn..(f + 1) * g.rn] {
                    acc = step(acc, v)?;
                }
            } else {
                let base = g.cell_base(f, &mut oi);
                for rf in 0..g.rn {
                    acc = step(acc, xs[g.elem_index(base, rf, &mut ri)])?;
                }
            }
            *slot = acc;
        }
        Ok(())
    };
    let mut out = vec![i0; g.n];
    let big = g.n.saturating_mul(g.rn) >= ELEM_PAR_MIN;
    shard_mut(&mut out, if big { workers } else { 1 }, run)?;
    Ok(out)
}

// ------------------------------------------------------------- scatter ---

/// StableHLO scatter index geometry, shared by every engine (the
/// tree-walking reference and the planned fused/generic paths) so the
/// batching-dims math exists exactly once: computes each update's full
/// operand index, drops out-of-bounds updates (XLA semantics), and
/// calls `apply(operand_index, update_index)` for the survivors in
/// ascending update order.
pub(crate) fn scatter_walk(
    operand_dims: &[usize],
    indices: &ArrayValue,
    updates: &ArrayValue,
    s: &ScatterDims,
    mut apply: impl FnMut(usize, usize) -> Result<()>,
) -> Result<()> {
    let orank = operand_dims.len();
    let sdims: Vec<usize> =
        (0..indices.dims.len()).filter(|&d| d != s.index_vector_dim).collect();
    let scatter_u: Vec<usize> = (0..updates.dims.len())
        .filter(|d| !s.update_window_dims.contains(d))
        .collect();
    let window_operand: Vec<usize> = (0..orank)
        .filter(|d| {
            !s.inserted_window_dims.contains(d) && !s.input_batching_dims.contains(d)
        })
        .collect();
    ensure!(
        window_operand.len() == s.update_window_dims.len(),
        "scatter window dims arity mismatch"
    );
    ensure!(scatter_u.len() == sdims.len(), "scatter batch rank mismatch");

    let pst = strides_of(operand_dims);
    let ust = strides_of(&updates.dims);
    let sst = strides_of(&indices.dims);
    let n = updates.numel();
    let mut ui = vec![0usize; updates.dims.len()];
    let mut full = vec![0i64; orank];
    for f in 0..n {
        unflatten(f, &ust, &mut ui);
        let mut sbase = 0;
        for (j, &sd) in sdims.iter().enumerate() {
            sbase += ui[scatter_u[j]] * sst[sd];
        }
        full.iter_mut().for_each(|v| *v = 0);
        for (k, &od) in s.scatter_dims_to_operand_dims.iter().enumerate() {
            let si = if s.index_vector_dim < indices.dims.len() {
                sbase + k * sst[s.index_vector_dim]
            } else {
                sbase
            };
            full[od] = indices.buf.index_at(si)?;
        }
        for (&od, &sd) in s.input_batching_dims.iter().zip(&s.scatter_indices_batching_dims) {
            let j = sdims.iter().position(|&x| x == sd).unwrap();
            full[od] = ui[scatter_u[j]] as i64;
        }
        for (k, &d) in window_operand.iter().enumerate() {
            full[d] += ui[s.update_window_dims[k]] as i64;
        }
        let in_bounds = full
            .iter()
            .zip(operand_dims)
            .all(|(&v, &d)| v >= 0 && (v as usize) < d);
        if !in_bounds {
            continue; // out-of-bounds updates are discarded
        }
        let pi: usize = full.iter().zip(&pst).map(|(&v, &st)| v as usize * st).sum();
        apply(pi, f)?;
    }
    Ok(())
}

// ----------------------------------------- convolution / reduce-window ---

/// Map (output coord, window tap) of one window dimension to an input
/// coordinate, or `None` when the tap lands in padding or between
/// base-dilation lattice points. The check order matters: negativity
/// BEFORE the modulo — `%` on a negative i64 keeps the sign, so a
/// negative position must be rejected before the lattice test for the
/// result to agree with the reference mirror's floor semantics.
pub(crate) fn resolve_window_pos(
    out_coord: usize,
    win_coord: usize,
    w: &WindowDim,
    in_size: usize,
) -> Option<usize> {
    let mut pos =
        out_coord as i64 * w.stride as i64 + win_coord as i64 * w.window_dilation as i64
            - w.pad_lo;
    if pos < 0 {
        return None;
    }
    if w.base_dilation > 1 {
        if pos % w.base_dilation as i64 != 0 {
            return None;
        }
        pos /= w.base_dilation as i64;
    }
    if pos >= in_size as i64 {
        return None;
    }
    Some(pos as usize)
}

/// Derived index geometry of a `reduce-window` over one operand shape,
/// shared by every engine (tree-walk reference, fused and generic
/// planned paths) so the visit-order-defining math exists exactly
/// once: output cells ascend in flat order; within a cell, window taps
/// ascend in row-major order over the window dimensions, and taps that
/// land in padding or dilation gaps are skipped entirely (exactly
/// "padding is init-valued" for any fold with identity init).
pub(crate) struct WindowGeom {
    window: Vec<WindowDim>,
    x_dims: Vec<usize>,
    pub out_dims: Vec<usize>,
    xst: Vec<usize>,
    ost: Vec<usize>,
    wst: Vec<usize>,
    /// window taps per output cell (including out-of-bounds taps)
    pub wn: usize,
    /// output cells
    pub n: usize,
}

impl WindowGeom {
    pub fn new(x_dims: &[usize], window: &[WindowDim]) -> Result<WindowGeom> {
        ensure!(
            window.len() == x_dims.len(),
            "reduce-window rank mismatch: window has {} dims, operand has {}",
            window.len(),
            x_dims.len()
        );
        let out_dims: Vec<usize> =
            window.iter().zip(x_dims).map(|(w, &n)| w.out_size(n)).collect();
        let wdims: Vec<usize> = window.iter().map(|w| w.size).collect();
        Ok(WindowGeom {
            xst: strides_of(x_dims),
            ost: strides_of(&out_dims),
            wst: strides_of(&wdims),
            wn: wdims.iter().product(),
            n: out_dims.iter().product(),
            window: window.to_vec(),
            x_dims: x_dims.to_vec(),
            out_dims,
        })
    }

    /// Scratch coordinate buffers for `cell_coords` / `tap_index`.
    pub fn scratch(&self) -> (Vec<usize>, Vec<usize>) {
        (vec![0; self.out_dims.len()], vec![0; self.window.len()])
    }

    /// Coordinates of output cell `f`.
    pub fn cell_coords(&self, f: usize, oi: &mut [usize]) {
        unflatten(f, &self.ost, oi);
    }

    /// Flat input index of window tap `wf` within the cell at `oi`, or
    /// `None` when the tap is out of bounds.
    pub fn tap_index(&self, oi: &[usize], wf: usize, wi: &mut [usize]) -> Option<usize> {
        unflatten(wf, &self.wst, wi);
        let mut xi = 0;
        for d in 0..self.x_dims.len() {
            let pos = resolve_window_pos(oi[d], wi[d], &self.window[d], self.x_dims[d])?;
            xi += pos * self.xst[d];
        }
        Some(xi)
    }
}

fn fold_window<T: Copy + Send + Sync>(
    g: &WindowGeom,
    xs: &[T],
    i0: T,
    step: impl Fn(T, T) -> Result<T> + Sync,
    acc_first: bool,
    workers: usize,
) -> Result<Vec<T>> {
    let run = |lo: usize, out: &mut [T]| -> Result<()> {
        let (mut oi, mut wi) = g.scratch();
        for (k, slot) in out.iter_mut().enumerate() {
            g.cell_coords(lo + k, &mut oi);
            let mut acc = i0;
            for wf in 0..g.wn {
                if let Some(xi) = g.tap_index(&oi, wf, &mut wi) {
                    let v = xs[xi];
                    acc = if acc_first { step(acc, v)? } else { step(v, acc)? };
                }
            }
            *slot = acc;
        }
        Ok(())
    };
    let mut out = vec![i0; g.n];
    let big = g.n.saturating_mul(g.wn) >= ELEM_PAR_MIN;
    shard_mut(&mut out, if big { workers } else { 1 }, run)?;
    Ok(out)
}

/// Fold every output cell of a fused single-binary-op `reduce-window`:
/// the identical tap visit order and scalar helpers as the generic
/// region path, so the result is bit-identical to it. Output cells
/// shard across `workers` scoped threads above [`ELEM_PAR_MIN`] total
/// taps; each cell's fold is computed by exactly one worker and chunks
/// merge in ascending order, so the result is also bit-identical at
/// any worker count (declared per-element in
/// [`crate::runtime::interp::verify::SHARD_REGISTRY`]).
pub fn reduce_window_fused(
    x: &ArrayValue,
    init: &ArrayValue,
    window: &[WindowDim],
    op: BinaryOp,
    acc_first: bool,
    workers: usize,
) -> Result<ArrayValue> {
    ensure!(init.dims.is_empty(), "reduce-window init must be scalar");
    let g = WindowGeom::new(&x.dims, window)?;
    let buf = match (&*x.buf, &*init.buf) {
        (Buf::F32(xs), Buf::F32(i)) => {
            Buf::F32(fold_window(&g, xs, i[0], |a, v| f32_bin(op, a, v), acc_first, workers)?)
        }
        (Buf::S32(xs), Buf::S32(i)) => {
            Buf::S32(fold_window(&g, xs, i[0], |a, v| s32_bin(op, a, v), acc_first, workers)?)
        }
        (Buf::U32(xs), Buf::U32(i)) => {
            Buf::U32(fold_window(&g, xs, i[0], |a, v| u32_bin(op, a, v), acc_first, workers)?)
        }
        (Buf::Pred(xs), Buf::Pred(i)) => {
            let f = pred_bin(op)?;
            Buf::Pred(fold_window(&g, xs, i[0], |a, v| Ok(f(a, v)), acc_first, workers)?)
        }
        _ => bail!("reduce-window operand/init type mismatch"),
    };
    Ok(ArrayValue { dims: g.out_dims, buf: Arc::new(buf) })
}

/// General `conv_general_dilated` as jax lowers it: output cells in
/// ascending flat order; per cell, kernel spatial taps row-major
/// ascending with the input channel innermost; one f32 accumulator
/// (every product and add rounds in f32, like the packed dot). Taps
/// that land in padding or base-dilation gaps are skipped entirely.
/// Feature and batch groups both use XLA's blocked indexing:
///
/// ```text
/// group       = oc / (O / feature_group_count)
/// batch_group = oc / (O / batch_group_count)
/// lhs_batch   = batch_group * (N / batch_group_count) + out_b
/// ```
///
/// Output cells shard across `workers` scoped threads when the total
/// multiply count reaches [`ELEM_PAR_MIN`]; each cell is computed by
/// exactly one worker with the same scalar loop it would see serially,
/// so the result is bit-identical at any worker count (declared
/// per-element in [`crate::runtime::interp::verify::SHARD_REGISTRY`]).
/// Validated bit-exactly against the reference mirror on the committed
/// img_tiny fixture (`tools/qnsim/plan_mirror.py`).
pub fn conv(
    lhs: &ArrayValue,
    rhs: &ArrayValue,
    d: &ConvDims,
    workers: usize,
) -> Result<ArrayValue> {
    let x = lhs.as_f32()?;
    let y = rhs.as_f32()?;
    let nsp = d.lhs_spatial.len();
    ensure!(
        d.window.len() == nsp && d.rhs_spatial.len() == nsp && d.out_spatial.len() == nsp,
        "convolution window/spatial rank mismatch"
    );
    ensure!(
        lhs.dims.len() == nsp + 2 && rhs.dims.len() == nsp + 2,
        "convolution operand rank mismatch"
    );
    let o_size = rhs.dims[d.rhs_output];
    let i_size = rhs.dims[d.rhs_input];
    let lb_size = lhs.dims[d.lhs_batch];
    let (fg, bg) = (d.feature_groups, d.batch_groups);
    ensure!(
        o_size % fg == 0 && o_size % bg == 0 && lb_size % bg == 0,
        "convolution group counts must divide the output-feature and batch dims"
    );
    ensure!(
        lhs.dims[d.lhs_feature] == i_size * fg,
        "convolution input feature dim {} != kernel input dim {i_size} x {fg} groups",
        lhs.dims[d.lhs_feature]
    );
    let mut out_dims = vec![0usize; nsp + 2];
    out_dims[d.out_batch] = lb_size / bg;
    out_dims[d.out_feature] = o_size;
    for s in 0..nsp {
        out_dims[d.out_spatial[s]] = d.window[s].out_size(lhs.dims[d.lhs_spatial[s]]);
    }
    let lst = strides_of(&lhs.dims);
    let rst = strides_of(&rhs.dims);
    let ost = strides_of(&out_dims);
    let kdims: Vec<usize> = d.rhs_spatial.iter().map(|&s| rhs.dims[s]).collect();
    let kst = strides_of(&kdims);
    let kn: usize = kdims.iter().product();
    let n: usize = out_dims.iter().product();
    let run = |lo: usize, chunk: &mut [f32]| -> Result<()> {
        let mut oi = vec![0usize; out_dims.len()];
        let mut ki = vec![0usize; kdims.len()];
        for (k, slot) in chunk.iter_mut().enumerate() {
            unflatten(lo + k, &ost, &mut oi);
            let oc = oi[d.out_feature];
            let g = oc / (o_size / fg);
            let bgi = oc / (o_size / bg);
            let b = bgi * (lb_size / bg) + oi[d.out_batch];
            let mut acc = 0.0f32;
            'tap: for kf in 0..kn {
                unflatten(kf, &kst, &mut ki);
                let mut lbase = b * lst[d.lhs_batch];
                for s in 0..nsp {
                    match resolve_window_pos(
                        oi[d.out_spatial[s]],
                        ki[s],
                        &d.window[s],
                        lhs.dims[d.lhs_spatial[s]],
                    ) {
                        Some(pos) => lbase += pos * lst[d.lhs_spatial[s]],
                        None => continue 'tap,
                    }
                }
                let mut rbase = oc * rst[d.rhs_output];
                for (s, &kc) in ki.iter().enumerate() {
                    rbase += kc * rst[d.rhs_spatial[s]];
                }
                for ic in 0..i_size {
                    let li = lbase + (g * i_size + ic) * lst[d.lhs_feature];
                    let ri = rbase + ic * rst[d.rhs_input];
                    acc += x[li] * y[ri];
                }
            }
            *slot = acc;
        }
        Ok(())
    };
    let mut out = vec![0f32; n];
    let big = n.saturating_mul(kn).saturating_mul(i_size) >= ELEM_PAR_MIN;
    shard_mut(&mut out, if big { workers } else { 1 }, run)?;
    Ok(ArrayValue { dims: out_dims, buf: Arc::new(Buf::F32(out)) })
}

/// `reverse`: flip the listed dimensions (a pure index remap; jax
/// emits it for the input-gradient convolution's kernel).
pub fn reverse(a: &ArrayValue, dims: &[usize]) -> Result<ArrayValue> {
    for &dd in dims {
        ensure!(dd < a.dims.len(), "reverse dimension {dd} out of range for {:?}", a.dims);
    }
    let xst = strides_of(&a.dims);
    let n = a.numel();
    let mut oi = vec![0usize; a.dims.len()];
    let mut buf = Buf::with_capacity(a.ty(), n);
    for f in 0..n {
        unflatten(f, &xst, &mut oi);
        let mut xi = 0;
        for (dd, &c) in oi.iter().enumerate() {
            let c = if dims.contains(&dd) { a.dims[dd] - 1 - c } else { c };
            xi += c * xst[dd];
        }
        buf.push_from(&a.buf, xi);
    }
    Ok(ArrayValue { dims: a.dims.clone(), buf: Arc::new(buf) })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(dims: &[usize], data: Vec<f32>) -> ArrayValue {
        ArrayValue::f32(dims, data).unwrap()
    }

    #[test]
    fn elementwise_f32() {
        let a = f(&[3], vec![1.0, -2.0, 4.0]);
        let b = f(&[3], vec![0.5, 2.0, -1.0]);
        let add = binary(BinaryOp::Add, &a, &b).unwrap();
        assert_eq!(add.as_f32().unwrap(), &[1.5, 0.0, 3.0]);
        let mx = binary(BinaryOp::Max, &a, &b).unwrap();
        assert_eq!(mx.as_f32().unwrap(), &[1.0, 2.0, 4.0]);
        let neg = unary(UnaryOp::Negate, &a).unwrap();
        assert_eq!(neg.as_f32().unwrap(), &[-1.0, 2.0, -4.0]);
        // round halves to even (the intN fake-quant convention)
        let r = unary(
            UnaryOp::RoundNearestEven,
            &f(&[4], vec![0.5, 1.5, 2.5, -0.5]),
        )
        .unwrap();
        assert_eq!(r.as_f32().unwrap(), &[0.0, 2.0, 2.0, -0.0]);
        // NaN propagates through maximum (unlike f32::max)
        let nan = binary(BinaryOp::Max, &f(&[1], vec![f32::NAN]), &f(&[1], vec![0.0])).unwrap();
        assert!(nan.as_f32().unwrap()[0].is_nan());
    }

    #[test]
    fn inplace_matches_allocating() {
        let a = f(&[4], vec![1.0, -2.0, 4.0, 0.25]);
        let b = f(&[4], vec![0.5, 2.0, -1.0, 3.0]);
        for op in [BinaryOp::Add, BinaryOp::Sub, BinaryOp::Div, BinaryOp::Max] {
            let want = binary(op, &a, &b).unwrap();
            // dst = lhs
            let mut d = (*a.buf).clone();
            binary_inplace(op, true, &mut d, &b.buf).unwrap();
            assert_eq!(d, *want.buf, "{op:?} lhs");
            // dst = rhs
            let mut d = (*b.buf).clone();
            binary_inplace(op, false, &mut d, &a.buf).unwrap();
            assert_eq!(d, *want.buf, "{op:?} rhs");
        }
        for op in [UnaryOp::Negate, UnaryOp::Exp, UnaryOp::Rsqrt] {
            let want = unary(op, &a).unwrap();
            let mut d = (*a.buf).clone();
            unary_inplace(op, &mut d).unwrap();
            assert_eq!(d, *want.buf, "{op:?}");
        }
        let pred = [true, false, false, true];
        let p = ArrayValue::new(vec![4], Buf::Pred(pred.to_vec())).unwrap();
        let want = select(&p, &a, &b).unwrap();
        let mut d = (*a.buf).clone();
        select_inplace(&pred, true, &mut d, &b.buf).unwrap();
        assert_eq!(d, *want.buf);
        let mut d = (*b.buf).clone();
        select_inplace(&pred, false, &mut d, &a.buf).unwrap();
        assert_eq!(d, *want.buf);
    }

    #[test]
    fn chain_apply_matches_composed_kernels_bitwise() {
        // select(x < exp(x), x * exp(x), splat) over awkward values,
        // composed from the allocating kernels vs one chain pass
        let n = ELEM_PAR_MIN + 7; // cross the sharding threshold
        let x = f(&[n], (0..n).map(|i| (i as f32 - 11.0) * 0.37).collect());
        let splat = 2.5f32;
        let s = f(&[n], vec![splat; n]);
        let e = unary(UnaryOp::Exp, &x).unwrap();
        let m = binary(BinaryOp::Mul, &x, &e).unwrap();
        let p = compare(CmpDir::Lt, &x, &e).unwrap();
        let want = select(&p, &m, &s).unwrap();

        // tape slots: 0 = x (also the in-place dst), 1 = splat;
        // ops write 2 = exp, 3 = mul, 4 = cmp, 5 = select
        let tape = [
            TapeOp::Unary { op: UnaryOp::Exp, ty: ElemType::F32, a: 0 },
            TapeOp::Binary { op: BinaryOp::Mul, ty: ElemType::F32, a: 0, b: 2 },
            TapeOp::Compare { dir: CmpDir::Lt, ty: ElemType::F32, a: 0, b: 2 },
            TapeOp::Select { p: 4, t: 3, f: 1 },
        ];
        for workers in [1, 3, 8] {
            let mut dst = (*x.buf).clone();
            let lanes = [LaneRef::Dst, LaneRef::Splat(splat.to_bits())];
            chain_apply(&tape, &lanes, &mut dst, workers).unwrap();
            let (Buf::F32(got), Buf::F32(w)) = (&dst, &*want.buf) else { panic!() };
            for (g, v) in got.iter().zip(w) {
                assert_eq!(g.to_bits(), v.to_bits(), "workers={workers}");
            }
        }
    }

    #[test]
    fn chain_convert_scalar_matches_convert_kernel() {
        // every (from, to) pair over tricky payloads, raw-payload vs
        // the allocating convert
        let f32s = [0.0f32, -0.0, 1.5, -2.7, 3.0e9, f32::NAN];
        let preds = [false, true];
        for &v in &f32s {
            let a = f(&[1], vec![v]);
            for to in [ElemType::F32, ElemType::S32, ElemType::U32, ElemType::Pred] {
                let want = convert(&a, to).unwrap();
                let got = convert_scalar(ElemType::F32, to, v.to_bits());
                let want_raw = match &*want.buf {
                    Buf::F32(x) => x[0].to_bits(),
                    Buf::S32(x) => x[0] as u32,
                    Buf::U32(x) => x[0],
                    Buf::Pred(x) => x[0] as u32,
                };
                assert_eq!(got, want_raw, "f32 {v} -> {}", to.name());
            }
        }
        for &v in &[0i32, 1, -1, i32::MIN, 7] {
            let a = ArrayValue { dims: vec![1], buf: Arc::new(Buf::S32(vec![v])) };
            for to in [ElemType::F32, ElemType::S32, ElemType::U32, ElemType::Pred] {
                let want = convert(&a, to).unwrap();
                let want_raw = match &*want.buf {
                    Buf::F32(x) => x[0].to_bits(),
                    Buf::S32(x) => x[0] as u32,
                    Buf::U32(x) => x[0],
                    Buf::Pred(x) => x[0] as u32,
                };
                assert_eq!(convert_scalar(ElemType::S32, to, v as u32), want_raw);
            }
        }
        for &v in &preds {
            let a = ArrayValue { dims: vec![1], buf: Arc::new(Buf::Pred(vec![v])) };
            for to in [ElemType::F32, ElemType::S32, ElemType::U32, ElemType::Pred] {
                let want = convert(&a, to).unwrap();
                let want_raw = match &*want.buf {
                    Buf::F32(x) => x[0].to_bits(),
                    Buf::S32(x) => x[0] as u32,
                    Buf::U32(x) => x[0],
                    Buf::Pred(x) => x[0] as u32,
                };
                assert_eq!(convert_scalar(ElemType::Pred, to, v as u32), want_raw);
            }
        }
    }

    #[test]
    fn threefry_kernel_matches_generic_hlo_composition() {
        // one round group computed via the exact u32_bin ops the
        // generic while body executes, vs the native kernel
        let rot = [13u32, 15, 26, 6];
        let (k0, k1) = (0x1BD1_1BDAu32, 0x9E37_79B9);
        let lanes: Vec<u32> = (0..100).map(|i| (i as u32).wrapping_mul(0x9E37_79B9)).collect();
        let mut x0: Vec<u32> = lanes.clone();
        let mut x1: Vec<u32> = lanes.iter().map(|v| v ^ 0xDEAD_BEEF).collect();
        let (gen0, gen1): (Vec<u32>, Vec<u32>) = x0
            .iter()
            .zip(&x1)
            .map(|(&a, &b)| {
                let (mut x, mut y) = (a, b);
                for &r in &rot {
                    x = u32_bin(BinaryOp::Add, x, y).unwrap();
                    let shl = u32_bin(BinaryOp::Shl, y, r).unwrap();
                    let s = u32_bin(BinaryOp::Sub, 32, r).unwrap();
                    let shr = u32_bin(BinaryOp::ShrLogical, y, s).unwrap();
                    y = u32_bin(BinaryOp::Xor, x, u32_bin(BinaryOp::Or, shl, shr).unwrap())
                        .unwrap();
                }
                (
                    u32_bin(BinaryOp::Add, x, k0).unwrap(),
                    u32_bin(BinaryOp::Add, y, k1).unwrap(),
                )
            })
            .unzip();
        threefry2x32(&mut x0, &mut x1, &rot, k0, k1, 1).unwrap();
        assert_eq!(x0, gen0);
        assert_eq!(x1, gen1);
    }

    #[test]
    fn rotl_xla_edge_rotations() {
        // r = 0 and r >= 32 follow the XLA shift composition, not a
        // CPU rotate instruction
        assert_eq!(rotl_xla(0x8000_0001, 0), 0x8000_0001);
        assert_eq!(rotl_xla(0x8000_0001, 1), 0x0000_0003);
        assert_eq!(rotl_xla(0x8000_0001, 31), 0xC000_0000);
        assert_eq!(rotl_xla(0x8000_0001, 32), 0); // both shifts yield 0
        assert_eq!(rotl_xla(0x8000_0001, 40), 0);
    }

    #[test]
    fn threefry_sharded_is_bit_identical() {
        let rot = [17u32, 29, 16, 24];
        let n = ELEM_PAR_MIN + 37; // above the sharding threshold
        let base0: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let base1: Vec<u32> = (0..n as u32).map(|i| i ^ 0xA5A5_A5A5).collect();
        let (mut s0, mut s1) = (base0.clone(), base1.clone());
        threefry2x32(&mut s0, &mut s1, &rot, 7, 11, 1).unwrap();
        for workers in [2usize, 3, 8] {
            let (mut p0, mut p1) = (base0.clone(), base1.clone());
            threefry2x32(&mut p0, &mut p1, &rot, 7, 11, workers).unwrap();
            assert_eq!(p0, s0, "workers={workers}");
            assert_eq!(p1, s1, "workers={workers}");
        }
    }

    #[test]
    fn sharded_inplace_elementwise_matches_serial() {
        let n = ELEM_PAR_MIN + 11;
        let a: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..n).map(|i| 0.5 + (i % 7) as f32).collect();
        let pred: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
        for workers in [2usize, 3, 8] {
            let mut serial = Buf::F32(a.clone());
            binary_inplace(BinaryOp::Div, false, &mut serial, &Buf::F32(b.clone())).unwrap();
            let mut sharded = Buf::F32(a.clone());
            binary_inplace_sharded(
                BinaryOp::Div,
                false,
                &mut sharded,
                &Buf::F32(b.clone()),
                workers,
            )
            .unwrap();
            assert_eq!(serial, sharded, "binary workers={workers}");

            let mut serial = Buf::F32(a.clone());
            unary_inplace(UnaryOp::Exp, &mut serial).unwrap();
            let mut sharded = Buf::F32(a.clone());
            unary_inplace_sharded(UnaryOp::Exp, &mut sharded, workers).unwrap();
            assert_eq!(serial, sharded, "unary workers={workers}");

            let mut serial = Buf::F32(a.clone());
            select_inplace(&pred, true, &mut serial, &Buf::F32(b.clone())).unwrap();
            let mut sharded = Buf::F32(a.clone());
            select_inplace_sharded(&pred, true, &mut sharded, &Buf::F32(b.clone()), workers)
                .unwrap();
            assert_eq!(serial, sharded, "select workers={workers}");
        }
    }

    #[test]
    fn fold_cells_sharded_matches_serial_contiguous_and_strided() {
        // 96 cells x 64 reduced elements, above the sharding threshold
        let dims = [96usize, 64];
        let xs: Vec<f32> = (0..dims[0] * dims[1]).map(|i| ((i * 37 % 101) as f32) - 50.0).collect();
        let step = |a: f32, v: f32| f32_bin(BinaryOp::Add, a, v);
        // contiguous: reduce the trailing dim; strided: the leading dim
        for red in [vec![1usize], vec![0]] {
            let g = ReduceGeom::new(&dims, &red);
            let serial = fold_cells(&g, &xs, 0.0f32, step, 1).unwrap();
            for workers in [2usize, 3, 8] {
                let sharded = fold_cells(&g, &xs, 0.0f32, step, workers).unwrap();
                let same = serial
                    .iter()
                    .zip(&sharded)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "red={red:?} workers={workers}");
            }
        }
    }

    fn wd(
        size: usize,
        stride: usize,
        pad_lo: i64,
        pad_hi: i64,
        base_dilation: usize,
        window_dilation: usize,
    ) -> WindowDim {
        WindowDim { size, stride, pad_lo, pad_hi, base_dilation, window_dilation }
    }

    #[test]
    fn conv_1d_same_padding() {
        // b0f_0io->b0f, SAME padding: hand-computed 1-D conv with two
        // output channels (oc1's kernel is asymmetric so orientation
        // errors would show)
        let lhs = f(&[1, 5, 1], vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let rhs = f(&[3, 1, 2], vec![1.0, 1.0, 1.0, 2.0, 1.0, 3.0]);
        let d = ConvDims {
            window: vec![wd(3, 1, 1, 1, 1, 1)],
            lhs_batch: 0,
            lhs_feature: 2,
            lhs_spatial: vec![1],
            rhs_input: 1,
            rhs_output: 2,
            rhs_spatial: vec![0],
            out_batch: 0,
            out_feature: 2,
            out_spatial: vec![1],
            feature_groups: 1,
            batch_groups: 1,
        };
        let out = conv(&lhs, &rhs, &d, 1).unwrap();
        assert_eq!(out.dims, vec![1, 5, 2]);
        assert_eq!(
            out.as_f32().unwrap(),
            &[3.0, 8.0, 6.0, 14.0, 9.0, 20.0, 12.0, 26.0, 9.0, 14.0]
        );
    }

    #[test]
    fn conv_feature_and_batch_groups() {
        // feature groups: oc 0 reads lhs channels {0,1}, oc 1 reads {2,3}
        let lhs = f(&[1, 1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let rhs = f(&[1, 2, 2], vec![1.0, 10.0, 2.0, 20.0]);
        let mut d = ConvDims {
            window: vec![wd(1, 1, 0, 0, 1, 1)],
            lhs_batch: 0,
            lhs_feature: 2,
            lhs_spatial: vec![1],
            rhs_input: 1,
            rhs_output: 2,
            rhs_spatial: vec![0],
            out_batch: 0,
            out_feature: 2,
            out_spatial: vec![1],
            feature_groups: 2,
            batch_groups: 1,
        };
        let out = conv(&lhs, &rhs, &d, 1).unwrap();
        assert_eq!(out.dims, vec![1, 1, 2]);
        assert_eq!(out.as_f32().unwrap(), &[5.0, 110.0]);
        // batch groups (the weight-grad lowering): oc 0 reads lhs batch
        // 0, oc 1 reads lhs batch 1, output batch extent collapses to 1
        let lhs = f(&[2, 1, 1], vec![3.0, 7.0]);
        let rhs = f(&[1, 1, 2], vec![10.0, 100.0]);
        d.feature_groups = 1;
        d.batch_groups = 2;
        let out = conv(&lhs, &rhs, &d, 1).unwrap();
        assert_eq!(out.dims, vec![1, 1, 2]);
        assert_eq!(out.as_f32().unwrap(), &[30.0, 700.0]);
    }

    #[test]
    fn conv_sharded_is_bit_identical() {
        // big enough that n * kn * i_size crosses ELEM_PAR_MIN
        let (h, w, cin, cout) = (12, 12, 3, 8);
        let lhs_n = h * w * cin;
        let lhs = f(&[1, h, w, cin], (0..lhs_n).map(|i| ((i * 37 % 101) as f32) - 50.0).collect());
        let rhs_n = 9 * cin * cout;
        let rhs =
            f(&[3, 3, cin, cout], (0..rhs_n).map(|i| ((i * 13 % 29) as f32) * 0.25).collect());
        let d = ConvDims {
            window: vec![wd(3, 1, 1, 1, 1, 1), wd(3, 1, 1, 1, 1, 1)],
            lhs_batch: 0,
            lhs_feature: 3,
            lhs_spatial: vec![1, 2],
            rhs_input: 2,
            rhs_output: 3,
            rhs_spatial: vec![0, 1],
            out_batch: 0,
            out_feature: 3,
            out_spatial: vec![1, 2],
            feature_groups: 1,
            batch_groups: 1,
        };
        let serial = conv(&lhs, &rhs, &d, 1).unwrap();
        for workers in [2usize, 3, 8] {
            let sharded = conv(&lhs, &rhs, &d, workers).unwrap();
            let same = serial
                .as_f32()
                .unwrap()
                .iter()
                .zip(sharded.as_f32().unwrap())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "workers={workers}");
        }
    }

    #[test]
    fn reverse_flips_listed_dims() {
        let a = f(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = reverse(&a, &[1]).unwrap();
        assert_eq!(r.as_f32().unwrap(), &[3.0, 2.0, 1.0, 6.0, 5.0, 4.0]);
        let r = reverse(&a, &[0, 1]).unwrap();
        assert_eq!(r.as_f32().unwrap(), &[6.0, 5.0, 4.0, 3.0, 2.0, 1.0]);
        // double reverse is the identity
        let rr = reverse(&reverse(&a, &[0, 1]).unwrap(), &[1, 0]).unwrap();
        assert_eq!(rr.as_f32().unwrap(), a.as_f32().unwrap());
        assert!(reverse(&a, &[2]).is_err());
    }

    #[test]
    fn reduce_window_fused_pools() {
        // stride-2 max pool with one column of high padding: the padded
        // tap is skipped, not folded as a value
        let x = f(&[5], vec![1.0, 5.0, 2.0, 4.0, 3.0]);
        let ninf = f(&[], vec![f32::NEG_INFINITY]);
        let out =
            reduce_window_fused(&x, &ninf, &[wd(2, 2, 0, 1, 1, 1)], BinaryOp::Max, true, 1)
                .unwrap();
        assert_eq!(out.dims, vec![3]);
        assert_eq!(out.as_f32().unwrap(), &[5.0, 4.0, 3.0]);
        // SAME add pool: edge cells fold fewer taps
        let zero = f(&[], vec![0.0]);
        let out = reduce_window_fused(&x, &zero, &[wd(3, 1, 1, 1, 1, 1)], BinaryOp::Add, true, 1)
            .unwrap();
        assert_eq!(out.as_f32().unwrap(), &[6.0, 8.0, 11.0, 9.0, 7.0]);
        // window dilation skips every other input element
        let out = reduce_window_fused(&x, &zero, &[wd(2, 1, 0, 0, 1, 2)], BinaryOp::Add, true, 1)
            .unwrap();
        assert_eq!(out.dims, vec![3]);
        assert_eq!(out.as_f32().unwrap(), &[3.0, 9.0, 5.0]);
        // init type must match the operand
        assert!(reduce_window_fused(
            &x,
            &ArrayValue::new(vec![], Buf::S32(vec![0])).unwrap(),
            &[wd(2, 1, 0, 0, 1, 1)],
            BinaryOp::Add,
            true,
            1
        )
        .is_err());
    }

    #[test]
    fn reduce_window_fused_sharded_is_bit_identical() {
        let n = ELEM_PAR_MIN; // n taps = 2 * ELEM_PAR_MIN, above threshold
        let x = f(&[n], (0..n).map(|i| ((i * 37 % 101) as f32) - 50.0).collect());
        let zero = f(&[], vec![0.0]);
        let win = [wd(2, 1, 1, 0, 1, 1)];
        let serial = reduce_window_fused(&x, &zero, &win, BinaryOp::Add, true, 1).unwrap();
        for workers in [2usize, 3, 8] {
            let sharded = reduce_window_fused(&x, &zero, &win, BinaryOp::Add, true, workers)
                .unwrap();
            let same = serial
                .as_f32()
                .unwrap()
                .iter()
                .zip(sharded.as_f32().unwrap())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "workers={workers}");
        }
    }

    #[test]
    fn u32_wrapping_and_shifts() {
        let a = ArrayValue::new(vec![2], Buf::U32(vec![u32::MAX, 0x89abcdef])).unwrap();
        let b = ArrayValue::new(vec![2], Buf::U32(vec![1, 13])).unwrap();
        let add = binary(BinaryOp::Add, &a, &b).unwrap();
        assert_eq!(*add.buf, Buf::U32(vec![0, 0x89abcdef + 13]));
        let shl = binary(BinaryOp::Shl, &a, &b).unwrap();
        assert_eq!(*shl.buf, Buf::U32(vec![u32::MAX << 1, 0x89abcdef << 13]));
        let shr = binary(BinaryOp::ShrLogical, &a, &b).unwrap();
        assert_eq!(*shr.buf, Buf::U32(vec![u32::MAX >> 1, 0x89abcdef >> 13]));
        // shift amounts >= 32 produce 0 (jax's threefry fold-in relies on it)
        let big = ArrayValue::new(vec![2], Buf::U32(vec![32, 40])).unwrap();
        let z = binary(BinaryOp::ShrLogical, &a, &big).unwrap();
        assert_eq!(*z.buf, Buf::U32(vec![0, 0]));
    }

    #[test]
    fn compare_and_select() {
        let a = f(&[3], vec![1.0, 2.0, 3.0]);
        let b = f(&[3], vec![2.0, 2.0, 2.0]);
        let lt = compare(CmpDir::Lt, &a, &b).unwrap();
        assert_eq!(lt.as_pred().unwrap(), &[true, false, false]);
        let ge = compare(CmpDir::Ge, &a, &b).unwrap();
        assert_eq!(ge.as_pred().unwrap(), &[false, true, true]);
        let sel = select(&lt, &a, &b).unwrap();
        assert_eq!(sel.as_f32().unwrap(), &[1.0, 2.0, 2.0]);
        // NaN compares false except NE
        let n = f(&[1], vec![f32::NAN]);
        let m = f(&[1], vec![0.0]);
        assert_eq!(compare(CmpDir::Eq, &n, &m).unwrap().as_pred().unwrap(), &[false]);
        assert_eq!(compare(CmpDir::Ne, &n, &m).unwrap().as_pred().unwrap(), &[true]);
    }

    #[test]
    fn convert_and_bitcast() {
        let a = f(&[2], vec![1.9, -2.9]);
        let s = convert(&a, ElemType::S32).unwrap(); // truncation toward zero
        assert_eq!(*s.buf, Buf::S32(vec![1, -2]));
        let neg = ArrayValue::new(vec![1], Buf::S32(vec![-1])).unwrap();
        let u = convert(&neg, ElemType::U32).unwrap(); // wraps mod 2^32
        assert_eq!(*u.buf, Buf::U32(vec![u32::MAX]));
        let one = f(&[1], vec![1.0]);
        let bits = bitcast_convert(&one, ElemType::U32).unwrap();
        assert_eq!(*bits.buf, Buf::U32(vec![0x3f80_0000]));
        let back = bitcast_convert(&bits, ElemType::F32).unwrap();
        assert_eq!(back.as_f32().unwrap(), &[1.0]);
    }

    #[test]
    fn iota_multidim() {
        let a = iota(ElemType::S32, &[2, 3], 0).unwrap();
        assert_eq!(*a.buf, Buf::S32(vec![0, 0, 0, 1, 1, 1]));
        let b = iota(ElemType::S32, &[2, 3], 1).unwrap();
        assert_eq!(*b.buf, Buf::S32(vec![0, 1, 2, 0, 1, 2]));
    }

    #[test]
    fn broadcast_scalar_and_vector() {
        let s = f(&[], vec![7.0]);
        let b = broadcast(&s, &[2, 2], &[]).unwrap();
        assert_eq!(b.as_f32().unwrap(), &[7.0; 4]);
        let v = f(&[2], vec![1.0, 2.0]);
        // map operand dim 0 to output dim 0: rows replicate
        let rows = broadcast(&v, &[2, 3], &[0]).unwrap();
        assert_eq!(rows.as_f32().unwrap(), &[1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        // map operand dim 0 to output dim 1: cols replicate
        let cols = broadcast(&v, &[3, 2], &[1]).unwrap();
        assert_eq!(cols.as_f32().unwrap(), &[1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
    }

    #[test]
    fn transpose_2d_and_4d() {
        let a = f(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let t = transpose(&a, &[1, 0]).unwrap();
        assert_eq!(t.dims, vec![3, 2]);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        // the attention pattern: (B,T,H,D) -> (B,H,T,D)
        let x = f(&[1, 2, 2, 1], vec![0.0, 1.0, 2.0, 3.0]);
        let y = transpose(&x, &[0, 2, 1, 3]).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[0.0, 2.0, 1.0, 3.0]);
    }

    #[test]
    fn slice_with_stride() {
        let a = f(&[6], (0..6).map(|i| i as f32).collect());
        let s = slice(&a, &[(1, 5, 2)]).unwrap();
        assert_eq!(s.as_f32().unwrap(), &[1.0, 3.0]);
        let m = f(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let s2 = slice(&m, &[(0, 2, 1), (1, 2, 1)]).unwrap();
        assert_eq!(s2.dims, vec![2, 1]);
        assert_eq!(s2.as_f32().unwrap(), &[2.0, 5.0]);
    }

    #[test]
    fn concatenate_axes() {
        let a = f(&[1, 2], vec![1.0, 2.0]);
        let b = f(&[1, 2], vec![3.0, 4.0]);
        let c0 = concatenate(&[&a, &b], 0).unwrap();
        assert_eq!(c0.dims, vec![2, 2]);
        assert_eq!(c0.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        let c1 = concatenate(&[&a, &b], 1).unwrap();
        assert_eq!(c1.dims, vec![1, 4]);
        assert_eq!(c1.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn dot_matmul_hand_checked() {
        // [2x3] @ [3x2], plain contraction on the inner dim
        let a = f(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = f(&[3, 2], vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let nums = DotDims {
            lhs_contracting: vec![1],
            rhs_contracting: vec![0],
            ..Default::default()
        };
        let c = dot(&a, &b, &nums).unwrap();
        assert_eq!(c.dims, vec![2, 2]);
        assert_eq!(c.as_f32().unwrap(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn dot_batched_hand_checked() {
        // batch dim 0 (size 2), contract dim 2 of lhs with dim 2 of rhs:
        // the attention-score einsum bhtd,bhsd->bhts collapsed to 3-D
        let a = f(&[2, 1, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = f(&[2, 1, 2], vec![5.0, 6.0, 7.0, 8.0]);
        let nums = DotDims {
            lhs_batch: vec![0],
            rhs_batch: vec![0],
            lhs_contracting: vec![2],
            rhs_contracting: vec![2],
        };
        let c = dot(&a, &b, &nums).unwrap();
        assert_eq!(c.dims, vec![2, 1, 1]);
        // batch 0: 1*5+2*6 = 17; batch 1: 3*7+4*8 = 53
        assert_eq!(c.as_f32().unwrap(), &[17.0, 53.0]);
    }

    #[test]
    fn gather_embedding_rows() {
        // embedding lookup: operand [4,2], indices [3,1] -> [3,2]
        let table = f(&[4, 2], vec![0.0, 0.1, 1.0, 1.1, 2.0, 2.1, 3.0, 3.1]);
        let idx = ArrayValue::new(vec![3, 1], Buf::S32(vec![2, 0, 3])).unwrap();
        let g = GatherDims {
            offset_dims: vec![1],
            collapsed_slice_dims: vec![0],
            start_index_map: vec![0],
            index_vector_dim: 1,
            slice_sizes: vec![1, 2],
            ..Default::default()
        };
        let out = gather(&table, &idx, &g, &[3, 2]).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[2.0, 2.1, 0.0, 0.1, 3.0, 3.1]);
    }

    #[test]
    fn gather_clamps_out_of_range_starts() {
        let table = f(&[4, 2], vec![0.0, 0.1, 1.0, 1.1, 2.0, 2.1, 3.0, 3.1]);
        let idx = ArrayValue::new(vec![2, 1], Buf::S32(vec![-5, 99])).unwrap();
        let g = GatherDims {
            offset_dims: vec![1],
            collapsed_slice_dims: vec![0],
            start_index_map: vec![0],
            index_vector_dim: 1,
            slice_sizes: vec![1, 2],
            ..Default::default()
        };
        let out = gather(&table, &idx, &g, &[2, 2]).unwrap();
        // clamped to rows 0 and 3
        assert_eq!(out.as_f32().unwrap(), &[0.0, 0.1, 3.0, 3.1]);
    }

    #[test]
    fn gather_rejects_oversized_slice() {
        // malformed module: slice larger than the operand dim must be a
        // typed error, not an arithmetic panic
        let table = f(&[4, 2], vec![0.0; 8]);
        let idx = ArrayValue::new(vec![1, 1], Buf::S32(vec![0])).unwrap();
        let g = GatherDims {
            offset_dims: vec![1],
            collapsed_slice_dims: vec![0],
            start_index_map: vec![0],
            index_vector_dim: 1,
            slice_sizes: vec![5, 2],
            ..Default::default()
        };
        assert!(gather(&table, &idx, &g, &[1, 2]).is_err());
    }

    #[test]
    fn gather_with_batching_dims() {
        // per-batch scalar pick: operand [2,3], indices [2,1]; batch dim
        // 0 of the operand pairs with dim 0 of the indices
        let x = f(&[2, 3], vec![10.0, 11.0, 12.0, 20.0, 21.0, 22.0]);
        let idx = ArrayValue::new(vec![2, 1], Buf::S32(vec![2, 0])).unwrap();
        let g = GatherDims {
            offset_dims: vec![],
            collapsed_slice_dims: vec![1],
            operand_batching_dims: vec![0],
            start_indices_batching_dims: vec![0],
            start_index_map: vec![1],
            index_vector_dim: 1,
            slice_sizes: vec![1, 1],
        };
        let out = gather(&x, &idx, &g, &[2]).unwrap();
        // batch 0 picks column 2 (12), batch 1 picks column 0 (20)
        assert_eq!(out.as_f32().unwrap(), &[12.0, 20.0]);
    }
}
