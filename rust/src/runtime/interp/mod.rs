//! Pure-Rust HLO-text interpreter backend.
//!
//! Parses the HLO text modules that `python/compile/aot.py` exports and
//! evaluates them directly — no PJRT plugin, no XLA shared library —
//! so the trainer/iPQ integration tests execute real grad/eval entries
//! in CI on the checked-in tiny-model fixture
//! (`rust/tests/fixtures/interp/`). See DESIGN.md §4 for the backend
//! split, the supported op inventory, and the determinism contract.
//!
//! Scope: the op set the tiny *Transformer and ConvNet* models lower
//! to (dot, elementwise arithmetic and bit ops, reduce, broadcast,
//! reshape, transpose, slice, concatenate, select, compare,
//! exp/log/rsqrt, sin/cos, iota, gather/scatter with batching dims,
//! general convolution with groups and dilations, reverse,
//! reduce-window, tuples, call, while, constants). jax's threefry PRNG
//! lowers to plain integer HLO, so in-graph noise sampling replays
//! exactly. Opcodes outside this set (e.g. `sort`) are reported as
//! unsupported at parse time.
//!
//! Execution is plan-and-execute: [`Plan::compile`] lowers a parsed
//! module once into a liveness-annotated instruction plan, and
//! [`Plan::run_entry`] executes it on reference-counted copy-on-write
//! buffers with in-place elementwise ops, fused reduce/scatter regions
//! and a packed (optionally sharded) dot. A fusion pass on top
//! ([`fuse`]) lowers counted `while` loops to a trip-counted
//! superinstruction and executes jax's threefry-2x32 PRNG round bodies
//! as a native u32 lane kernel ([`ops::threefry2x32`]); fused reduces,
//! large elementwise ops and threefry lanes shard across scoped
//! workers above a size threshold, all bit-deterministically. The
//! tree-walking [`Interp`] remains as the bit-exact reference engine
//! the plan is golden-tested against (`tests/interp_plan.rs`,
//! `tests/interp_fuse.rs`); `QN_INTERP_STATS=1` prints a per-op
//! execution histogram ([`stats`]) when a plan drops.
//!
//! ```text
//!   HLO text ──parser──▶ HloModule ──Plan::compile──▶ Plan ──run_entry──▶ Value tuple
//!                                  └─Interp::run_entry (reference oracle)─┘
//! ```

pub mod eval;
pub mod fuse;
pub mod ops;
pub mod parser;
pub mod plan;
pub mod stats;
pub mod value;
pub mod verify;

pub use eval::Interp;
pub use parser::HloModule;
pub use plan::{FusionStats, Plan, PlanOptions};
pub use value::{ArrayValue, Buf, ElemType, Shape, Value};
pub use verify::{Diagnostic, PlanCensus};

#[cfg(test)]
mod tests {
    use super::*;

    /// A miniature end-to-end module exercising parse → eval together:
    /// mean((x @ w) + b) — the core shape of every artifact entry.
    #[test]
    fn parse_and_run_linear_mean() {
        let text = "HloModule smoke, entry_computation_layout={(f32[2,2]{1,0},\
                    f32[2,2]{1,0},f32[2]{0})->f32[]}\n\n\
                    sum.1 {\n  a.1 = f32[] parameter(0)\n  b.2 = f32[] parameter(1)\n  \
                    ROOT add.3 = f32[] add(a.1, b.2)\n}\n\n\
                    ENTRY main.1 {\n  x.1 = f32[2,2]{1,0} parameter(0)\n  \
                    w.2 = f32[2,2]{1,0} parameter(1)\n  b.3 = f32[2]{0} parameter(2)\n  \
                    d.4 = f32[2,2]{1,0} dot(x.1, w.2), lhs_contracting_dims={1}, \
                    rhs_contracting_dims={0}\n  \
                    bb.5 = f32[2,2]{1,0} broadcast(b.3), dimensions={1}\n  \
                    s.6 = f32[2,2]{1,0} add(d.4, bb.5)\n  z.7 = f32[] constant(0)\n  \
                    r.8 = f32[] reduce(s.6, z.7), dimensions={0,1}, to_apply=sum.1\n  \
                    four.9 = f32[] constant(4)\n  \
                    ROOT m.10 = f32[] divide(r.8, four.9)\n}\n";
        let m = HloModule::parse_str(text).unwrap();
        let x = Value::Array(ArrayValue::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap());
        let w = Value::Array(ArrayValue::f32(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap());
        let b = Value::Array(ArrayValue::f32(&[2], vec![0.5, -0.5]).unwrap());
        let out = Interp::new(&m).run_entry(&[x, w, b]).unwrap();
        // x@I + b = [[1.5,1.5],[3.5,3.5]]; mean = 2.5
        let got = out.array().unwrap().as_f32().unwrap()[0];
        assert!((got - 2.5).abs() < 1e-6, "{got}");
    }

    #[test]
    fn unsupported_op_reports_name() {
        let text = "HloModule bad\n\nENTRY main.1 {\n  x.1 = f32[2,2]{1,0} parameter(0)\n  \
                    ROOT s.2 = f32[2,2]{1,0} sort(x.1), dimensions={0}\n}\n";
        let err = format!("{:#}", HloModule::parse_str(text).unwrap_err());
        assert!(err.contains("sort"), "{err}");
    }
}
