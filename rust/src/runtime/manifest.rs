//! Loads `artifacts/manifest.json` — the contract between the AOT
//! exporter and the Rust runtime (entry points, input orders, parameter
//! inventory, init file).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::config::ModelMeta;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let mut models = BTreeMap::new();
        if let Some(obj) = j.get("models").as_obj() {
            for (name, mj) in obj {
                let meta = ModelMeta::from_json(name, mj)
                    .with_context(|| format!("parsing model {name}"))?;
                models.insert(name.clone(), meta);
            }
        }
        anyhow::ensure!(!models.is_empty(), "manifest has no models");
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).with_context(|| {
            format!(
                "model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn hlo_path(&self, meta: &ModelMeta, entry: &str) -> Result<PathBuf> {
        let e = meta
            .entry(entry)
            .with_context(|| format!("model {} has no entry '{entry}'", meta.name))?;
        Ok(self.dir.join(&e.file))
    }

    pub fn init_path(&self, meta: &ModelMeta) -> PathBuf {
        self.dir.join(&meta.init_file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::temp_dir;

    #[test]
    fn load_rejects_missing() {
        let dir = temp_dir("man");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_minimal() {
        let dir = temp_dir("man2");
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "models": {"m": {
                "task": "lm", "n_layers": 1, "batch": 2, "seq_len": 4,
                "tokens_shape": [2,4], "targets_shape": [2,4],
                "vocab": 10, "n_classes": 0, "init": "m.init.bin",
                "params": [], "entries": {"eval": {"file": "m.eval.hlo.txt",
                    "inputs": ["tokens"], "outputs": ["sum_nll"]}}}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let meta = m.model("m").unwrap();
        assert_eq!(meta.task, "lm");
        assert!(m.hlo_path(meta, "eval").unwrap().ends_with("m.eval.hlo.txt"));
        assert!(m.hlo_path(meta, "nope").is_err());
        assert!(m.model("other").is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
