//! Runtime client: loads HLO-text artifacts and executes them on a
//! selectable backend (DESIGN.md §4).
//!
//! Two backends sit behind one `Runtime` handle:
//!
//! * **Interp** — the pure-Rust HLO interpreter
//!   ([`crate::runtime::interp`]). Works offline, deterministic,
//!   covers the tiny Transformer op set. The default.
//! * **Pjrt** — the vendored `xla` PJRT binding. In this offline build
//!   it is a compile-time stub whose compile/execute paths error at
//!   runtime; with a real `xla` crate dropped into `rust/vendor/xla`
//!   the same seam runs compiled XLA.
//!
//! Selection: `Runtime::cpu()` honours the `QN_BACKEND` environment
//! variable (`interp` default, `pjrt` opt-in); tests that must execute
//! the fixture use `Runtime::interp()` explicitly.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::runtime::interp::{self, ArrayValue, Buf, Interp, Value};

/// Which execution engine a [`Runtime`] drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust HLO-text interpreter (offline, deterministic).
    Interp,
    /// PJRT via the vendored `xla` crate (stubbed in offline builds).
    Pjrt,
}

impl Backend {
    /// Backend choice from `QN_BACKEND`: `interp` (default when unset)
    /// or `pjrt`. Anything else is an error — a typo must not silently
    /// hand back the interpreter.
    pub fn from_env() -> Result<Backend> {
        match std::env::var("QN_BACKEND").as_deref() {
            Err(_) | Ok("") | Ok("interp") => Ok(Backend::Interp),
            Ok("pjrt") => Ok(Backend::Pjrt),
            Ok(other) => bail!("QN_BACKEND must be 'interp' or 'pjrt', got '{other}'"),
        }
    }
}

/// A loaded, executable artifact on some backend.
pub enum Executable {
    Interp(interp::HloModule),
    Pjrt(xla::PjRtLoadedExecutable),
}

impl Executable {
    /// Execute and download the result. Every artifact entry returns a
    /// flat tuple of f32 arrays (loss+grads, or eval sums) — see the
    /// entry-point contract in DESIGN.md §1 — so that is the one
    /// download shape this seam needs.
    pub fn execute_f32(&self, args: &[&Buffer]) -> Result<Vec<Vec<f32>>> {
        match self {
            Executable::Interp(module) => {
                let vals: Vec<Value> = args
                    .iter()
                    .map(|b| match b {
                        Buffer::Host(a) => Ok(Value::Array(a.clone())),
                        Buffer::Pjrt(_) => bail!("PJRT buffer passed to the interpreter backend"),
                    })
                    .collect::<Result<_>>()?;
                let out = Interp::new(module).run_entry(&vals)?;
                out.tuple()
                    .context("artifact entry did not return a tuple")?
                    .iter()
                    .map(|v| Ok(v.array()?.as_f32()?.to_vec()))
                    .collect()
            }
            Executable::Pjrt(exe) => {
                let bufs: Vec<&xla::PjRtBuffer> = args
                    .iter()
                    .map(|b| match b {
                        Buffer::Pjrt(p) => Ok(p),
                        Buffer::Host(_) => bail!("interpreter buffer passed to the PJRT backend"),
                    })
                    .collect::<Result<_>>()?;
                let result = exe.execute_b(&bufs).context("executing on PJRT")?;
                let lit = result[0][0].to_literal_sync().context("downloading result")?;
                lit.to_tuple()
                    .context("decomposing result tuple")?
                    .into_iter()
                    .map(|p| p.to_vec::<f32>().context("tuple element to f32"))
                    .collect()
            }
        }
    }
}

/// A device (or host) buffer on some backend.
pub enum Buffer {
    Host(ArrayValue),
    Pjrt(xla::PjRtBuffer),
}

pub struct Runtime {
    backend: Backend,
    pjrt: Option<xla::PjRtClient>,
    cache: Mutex<HashMap<PathBuf, Rc<Executable>>>,
}

impl Runtime {
    /// Default runtime: backend selected by `QN_BACKEND` (interp unless
    /// overridden).
    pub fn cpu() -> Result<Runtime> {
        Runtime::with_backend(Backend::from_env()?)
    }

    /// The interpreter backend, unconditionally (what the fixture-driven
    /// integration tests use).
    pub fn interp() -> Runtime {
        Runtime { backend: Backend::Interp, pjrt: None, cache: Mutex::new(HashMap::new()) }
    }

    pub fn with_backend(backend: Backend) -> Result<Runtime> {
        let pjrt = match backend {
            Backend::Interp => None,
            Backend::Pjrt => Some(xla::PjRtClient::cpu().context("creating PJRT CPU client")?),
        };
        Ok(Runtime { backend, pjrt, cache: Mutex::new(HashMap::new()) })
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    pub fn platform(&self) -> String {
        match (&self.backend, &self.pjrt) {
            (Backend::Interp, _) => "interp-cpu".to_string(),
            (Backend::Pjrt, Some(c)) => c.platform_name(),
            (Backend::Pjrt, None) => unreachable!("PJRT backend without client"),
        }
    }

    /// Load + compile an HLO text file (cached by path).
    pub fn compile(&self, path: &Path) -> Result<Rc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let exe = Rc::new(match self.backend {
            Backend::Interp => Executable::Interp(interp::HloModule::parse_file(path)?),
            Backend::Pjrt => {
                let client = self.pjrt.as_ref().expect("PJRT backend without client");
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                Executable::Pjrt(
                    client
                        .compile(&comp)
                        .with_context(|| format!("compiling {}", path.display()))?,
                )
            }
        });
        self.cache.lock().unwrap().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    // ------------------------------------------------ host ⇄ device ---

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        match self.backend {
            Backend::Interp => Ok(Buffer::Host(
                ArrayValue::new(dims.to_vec(), Buf::F32(data.to_vec()))
                    .context("uploading f32 buffer")?,
            )),
            Backend::Pjrt => {
                let client = self.pjrt.as_ref().expect("PJRT backend without client");
                Ok(Buffer::Pjrt(
                    client
                        .buffer_from_host_buffer(data, dims, None)
                        .context("uploading f32 buffer")?,
                ))
            }
        }
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        match self.backend {
            Backend::Interp => Ok(Buffer::Host(
                ArrayValue::new(dims.to_vec(), Buf::S32(data.to_vec()))
                    .context("uploading i32 buffer")?,
            )),
            Backend::Pjrt => {
                let client = self.pjrt.as_ref().expect("PJRT backend without client");
                Ok(Buffer::Pjrt(
                    client
                        .buffer_from_host_buffer(data, dims, None)
                        .context("uploading i32 buffer")?,
                ))
            }
        }
    }

    pub fn scalar_f32(&self, v: f32) -> Result<Buffer> {
        self.upload_f32(&[v], &[])
    }

    pub fn scalar_i32(&self, v: i32) -> Result<Buffer> {
        self.upload_i32(&[v], &[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_backend_is_default_and_uploads() {
        let rt = Runtime::interp();
        assert_eq!(rt.backend(), Backend::Interp);
        assert_eq!(rt.platform(), "interp-cpu");
        let b = rt.upload_f32(&[1.0, 2.0], &[2]).unwrap();
        match b {
            Buffer::Host(a) => assert_eq!(a.as_f32().unwrap(), &[1.0, 2.0]),
            Buffer::Pjrt(_) => panic!("interp runtime produced a PJRT buffer"),
        }
        // shape mismatches are rejected at upload time
        assert!(rt.upload_f32(&[1.0; 5], &[2, 2]).is_err());
        // scalars are rank-0 one-element arrays
        match rt.scalar_i32(7).unwrap() {
            Buffer::Host(a) => {
                assert!(a.dims.is_empty());
                assert_eq!(a.buf, Buf::S32(vec![7]));
            }
            Buffer::Pjrt(_) => panic!(),
        }
    }

    #[test]
    fn pjrt_backend_still_constructs() {
        // the stub client builds; real compile/execute paths error — the
        // seam itself must stay usable for a future real xla crate
        let rt = Runtime::with_backend(Backend::Pjrt).unwrap();
        // don't assert the exact platform string: a real vendored xla
        // reports its own name, and this test must keep passing then
        assert!(!rt.platform().is_empty() && rt.platform() != "interp-cpu");
        assert!(rt.upload_f32(&[0.5], &[1]).is_ok());
        assert!(rt.compile(Path::new("/nonexistent.hlo.txt")).is_err());
    }

    #[test]
    fn compile_caches_by_path() {
        let dir = crate::util::testing::temp_dir("interp_cache");
        let path = dir.join("m.hlo.txt");
        std::fs::write(
            &path,
            "HloModule m\n\nENTRY main.1 {\n  x.1 = f32[2]{0} parameter(0)\n  \
             ROOT d.2 = f32[2]{0} add(x.1, x.1)\n}\n",
        )
        .unwrap();
        let rt = Runtime::interp();
        let a = rt.compile(&path).unwrap();
        let b = rt.compile(&path).unwrap();
        assert!(Rc::ptr_eq(&a, &b), "second compile must hit the cache");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn execute_f32_runs_tuple_entry() {
        let dir = crate::util::testing::temp_dir("interp_exec");
        let path = dir.join("m.hlo.txt");
        std::fs::write(
            &path,
            "HloModule m\n\nENTRY main.1 {\n  x.1 = f32[2]{0} parameter(0)\n  \
             s.2 = f32[2]{0} multiply(x.1, x.1)\n  \
             ROOT t.3 = (f32[2]{0}, f32[2]{0}) tuple(x.1, s.2)\n}\n",
        )
        .unwrap();
        let rt = Runtime::interp();
        let exe = rt.compile(&path).unwrap();
        let arg = rt.upload_f32(&[3.0, -2.0], &[2]).unwrap();
        let out = exe.execute_f32(&[&arg]).unwrap();
        assert_eq!(out, vec![vec![3.0, -2.0], vec![9.0, 4.0]]);
        std::fs::remove_dir_all(dir).ok();
    }
}
