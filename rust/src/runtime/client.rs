//! Runtime client: loads HLO-text artifacts and executes them on a
//! selectable backend (DESIGN.md §4).
//!
//! Two backends sit behind one `Runtime` handle:
//!
//! * **Interp** — the pure-Rust HLO interpreter
//!   ([`crate::runtime::interp`]), compiled at load time into a
//!   liveness-annotated [`interp::Plan`] and executed in place. Works
//!   offline, deterministic, covers the tiny Transformer op set. The
//!   default.
//! * **Pjrt** — the vendored `xla` PJRT binding. In this offline build
//!   it is a compile-time stub whose compile/execute paths error at
//!   runtime; with a real `xla` crate dropped into `rust/vendor/xla`
//!   the same seam runs compiled XLA.
//!
//! Selection: `Runtime::cpu()` honours the `QN_BACKEND` environment
//! variable (`interp` default, `pjrt` opt-in); tests that must execute
//! the fixture use `Runtime::interp()` explicitly.
//!
//! Parallelism: [`Runtime::set_threads`] bounds the interpreter's
//! worker count — intra-op sharding inside one invocation
//! ([`Executable::execute_f32_with`]) and batch sharding across
//! independent invocations ([`Executable::execute_f32_batched`]). Both
//! are bit-deterministic at any thread count (DESIGN.md §4).

// caches here are keyed lookup only — iteration order never reaches
// results (clippy.toml bans HashMap in order-defining paths)
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, ensure, Context, Result};

use crate::runtime::interp::{self, ArrayValue, Buf, Value};

// ----------------------------------------------------------- plan cache ---

/// Process-wide compiled-plan cache, keyed by the HLO *text* itself
/// (exact equality — a few artifacts of a few hundred KB each, so the
/// stored keys are cheap and there is no hash-collision hazard):
/// loading the same entry twice — the trainer's `grad_mix` + `eval`
/// sessions, repeated `Workbench` runs, a fresh [`Runtime`] per
/// experiment — re-parses and re-plans zero times. Plans are immutable
/// and `Send + Sync`, so one [`interp::Plan`] serves every runtime.
/// (Bypassed under `QN_INTERP_STATS`: the histogram prints when a plan
/// drops, and entries in a process-wide cache never would.)
static PLAN_CACHE: OnceLock<Mutex<HashMap<String, Arc<interp::Plan>>>> = OnceLock::new();
static PLAN_CACHE_HITS: AtomicU64 = AtomicU64::new(0);
static PLAN_CACHE_MISSES: AtomicU64 = AtomicU64::new(0);

/// Lifetime (hits, misses) of the process-wide plan cache.
pub fn plan_cache_stats() -> (u64, u64) {
    (PLAN_CACHE_HITS.load(Ordering::Relaxed), PLAN_CACHE_MISSES.load(Ordering::Relaxed))
}

/// Parse + plan `text`, via the content cache unless stats mode wants
/// per-session plan lifetimes. The compiled plan passes the static
/// verifier *before* it can reach the cache (debug builds and
/// `QN_PLAN_VERIFY=1`): a rejected plan surfaces as a load error with
/// the diagnostics, never as a cached executable.
fn plan_for_text(text: &str, path: &Path) -> Result<Arc<interp::Plan>> {
    let parse_and_plan = || -> Result<Arc<interp::Plan>> {
        let module = interp::HloModule::parse_str(text)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let plan =
            interp::Plan::compile_unverified(&module, interp::PlanOptions::default());
        if interp::verify::should_verify() {
            let diags = interp::verify::verify(&plan);
            ensure!(
                diags.is_empty(),
                "plan verification failed for {}:\n{}",
                path.display(),
                interp::verify::render(&diags)
            );
        }
        Ok(Arc::new(plan))
    };
    if std::env::var("QN_INTERP_STATS").map(|v| !v.is_empty() && v != "0").unwrap_or(false) {
        return parse_and_plan();
    }
    let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(plan) = cache.lock().unwrap().get(text) {
        PLAN_CACHE_HITS.fetch_add(1, Ordering::Relaxed);
        return Ok(plan.clone());
    }
    let plan = parse_and_plan()?;
    PLAN_CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
    Ok(cache.lock().unwrap().entry(text.to_string()).or_insert(plan).clone())
}

/// Which execution engine a [`Runtime`] drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust HLO-text interpreter (offline, deterministic).
    Interp,
    /// PJRT via the vendored `xla` crate (stubbed in offline builds).
    Pjrt,
}

impl Backend {
    /// Backend choice from `QN_BACKEND`: `interp` (default when unset)
    /// or `pjrt`. Anything else is an error — a typo must not silently
    /// hand back the interpreter.
    pub fn from_env() -> Result<Backend> {
        match std::env::var("QN_BACKEND").as_deref() {
            Err(_) | Ok("") | Ok("interp") => Ok(Backend::Interp),
            Ok("pjrt") => Ok(Backend::Pjrt),
            Ok(other) => bail!("QN_BACKEND must be 'interp' or 'pjrt', got '{other}'"),
        }
    }
}

/// Typed "this backend cannot do that" failure, carried as an
/// `anyhow` payload so callers can separate a declined capability —
/// the vendored PJRT stub, or a capability a real plugin lacks — from
/// bad input or an internal bug. The serving layer downcasts to this
/// to answer 503 Service Unavailable per request instead of treating
/// the condition as a server error (DESIGN.md §9).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackendError {
    pub backend: Backend,
    pub what: String,
}

impl std::fmt::Display for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?} backend unavailable: {}", self.backend, self.what)
    }
}

impl std::error::Error for BackendError {}

impl BackendError {
    /// An `anyhow` error with a [`BackendError`] payload attached
    /// (retrieve with `err.downcast_ref::<BackendError>()`).
    pub fn unavailable(backend: Backend, what: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(BackendError { backend, what: what.into() })
    }
}

/// Lift an `xla` crate error: `Unavailable` (the stub declining real
/// work) becomes a typed [`BackendError`]; anything else stays a plain
/// message.
fn pjrt_err(e: xla::Error, what: &str) -> anyhow::Error {
    match &e {
        xla::Error::Unavailable(_) => {
            BackendError::unavailable(Backend::Pjrt, format!("{what}: {e}"))
        }
        _ => anyhow::anyhow!("{what}: {e}"),
    }
}

/// A loaded, executable artifact on some backend. Interpreter plans
/// are `Arc`-shared through the process-wide content cache.
pub enum Executable {
    Interp(Arc<interp::Plan>),
    Pjrt(xla::PjRtLoadedExecutable),
}

/// One entry invocation's downloaded result tuple.
type ShardResult = Result<Vec<Vec<f32>>>;

/// Download one planned invocation's result tuple as f32 vectors.
fn download_f32(out: Value) -> ShardResult {
    out.tuple()
        .context("artifact entry did not return a tuple")?
        .iter()
        .map(|v| Ok(v.array()?.as_f32()?.to_vec()))
        .collect()
}

fn host_array(b: &Buffer) -> Result<&ArrayValue> {
    match b {
        Buffer::Host(a) => Ok(a),
        Buffer::Pjrt(_) => bail!("PJRT buffer passed to the interpreter backend"),
    }
}

impl Executable {
    /// Execute and download the result. Every artifact entry returns a
    /// flat tuple of f32 arrays (loss+grads, or eval sums) — see the
    /// entry-point contract in DESIGN.md §1 — so that is the one
    /// download shape this seam needs. Single-threaded; use
    /// [`Executable::execute_f32_with`] to bound intra-op workers.
    pub fn execute_f32(&self, args: &[&Buffer]) -> Result<Vec<Vec<f32>>> {
        self.execute_f32_with(args, 1)
    }

    /// [`Executable::execute_f32`] with an explicit worker bound for
    /// the interpreter's intra-op sharding (packed dot). Results are
    /// bit-identical for every `threads` value.
    pub fn execute_f32_with(&self, args: &[&Buffer], threads: usize) -> Result<Vec<Vec<f32>>> {
        match self {
            Executable::Interp(plan) => {
                let vals: Vec<Value> = args
                    .iter()
                    .map(|b| Ok(Value::Array(host_array(b)?.clone())))
                    .collect::<Result<_>>()?;
                download_f32(plan.run_entry(vals, threads)?)
            }
            Executable::Pjrt(exe) => {
                let bufs: Vec<&xla::PjRtBuffer> = args
                    .iter()
                    .map(|b| match b {
                        Buffer::Pjrt(p) => Ok(p),
                        Buffer::Host(_) => bail!("interpreter buffer passed to the PJRT backend"),
                    })
                    .collect::<Result<_>>()?;
                let result = exe.execute_b(&bufs).map_err(|e| pjrt_err(e, "executing on PJRT"))?;
                let lit = result[0][0]
                    .to_literal_sync()
                    .map_err(|e| pjrt_err(e, "downloading result"))?;
                lit.to_tuple()
                    .context("decomposing result tuple")?
                    .into_iter()
                    .map(|p| p.to_vec::<f32>().context("tuple element to f32"))
                    .collect()
            }
        }
    }

    /// Deterministic data parallelism over the leading batch dimension
    /// (interpreter backend only).
    ///
    /// Inputs whose dims match the entry's declared parameter shape are
    /// replicated (O(1) — shared buffers); inputs whose leading dim is
    /// an integer multiple `M` of the declared one are sliced into `M`
    /// shards. Each shard is an independent entry invocation with fixed
    /// visit order, executed across at most `threads` scoped workers,
    /// and the per-shard result tuples are returned in ascending shard
    /// order — so the output is bit-identical across 1..N threads
    /// (the `quant::assign` determinism contract, DESIGN.md §4).
    pub fn execute_f32_batched(
        &self,
        args: &[&Buffer],
        threads: usize,
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        let plan = match self {
            Executable::Interp(plan) => plan,
            Executable::Pjrt(_) => {
                return Err(BackendError::unavailable(
                    Backend::Pjrt,
                    "batched execution is interpreter-only (DESIGN.md §4)",
                ));
            }
        };
        ensure!(
            args.len() == plan.n_entry_params(),
            "entry takes {} inputs, got {}",
            plan.n_entry_params(),
            args.len()
        );
        enum Slot<'a> {
            Shared(&'a ArrayValue),
            Batched { a: &'a ArrayValue, rows: usize },
        }
        let mut m: Option<usize> = None;
        let mut slots = Vec::with_capacity(args.len());
        for (i, b) in args.iter().enumerate() {
            let a = host_array(b)?;
            let expected = plan.entry_param_shape(i).map(|s| s.array()).transpose()?;
            let slot = match expected {
                None => Slot::Shared(a),
                Some((_, dims)) if a.dims == dims => Slot::Shared(a),
                Some((_, dims)) => {
                    ensure!(
                        !dims.is_empty()
                            && a.dims.len() == dims.len()
                            && a.dims[1..] == dims[1..]
                            && dims[0] > 0
                            && a.dims[0] % dims[0] == 0,
                        "input {i}: dims {:?} neither match entry shape {:?} nor batch it",
                        a.dims,
                        dims
                    );
                    let mi = a.dims[0] / dims[0];
                    match m {
                        None => m = Some(mi),
                        Some(prev) => {
                            ensure!(prev == mi, "inconsistent batch factors {prev} vs {mi}")
                        }
                    }
                    Slot::Batched { a, rows: dims[0] }
                }
            };
            slots.push(slot);
        }
        let m = m.unwrap_or(1);
        // per-shard argument construction (runs inside the workers)
        let build = |s: usize| -> Result<Vec<Value>> {
            slots
                .iter()
                .map(|slot| match slot {
                    Slot::Shared(a) => Ok(Value::Array((*a).clone())),
                    Slot::Batched { a, rows } => {
                        let inner: usize = a.dims[1..].iter().product();
                        let lo = s * rows * inner;
                        let mut dims = a.dims.clone();
                        dims[0] = *rows;
                        let buf = a.buf.copy_range(lo, lo + rows * inner);
                        Ok(Value::Array(ArrayValue::new(dims, buf)?))
                    }
                })
                .collect()
        };
        let workers = threads.max(1).min(m);
        // hand any leftover thread budget to each shard's intra-op
        // sharding (fewer shards than cores): still deterministic —
        // intra-op results are thread-count-invariant
        let inner = (threads.max(1) / workers.max(1)).max(1);
        let run_shard = |s: usize| -> ShardResult {
            download_f32(plan.run_entry(build(s)?, inner)?)
                .with_context(|| format!("executing batch shard {s}/{m}"))
        };
        let mut results: Vec<Option<ShardResult>> = (0..m).map(|_| None).collect();
        if workers <= 1 {
            for (s, slot) in results.iter_mut().enumerate() {
                *slot = Some(run_shard(s));
            }
        } else {
            let chunk = m.div_ceil(workers);
            let run_shard = &run_shard;
            std::thread::scope(|sc| {
                for (ci, rc) in results.chunks_mut(chunk).enumerate() {
                    sc.spawn(move || {
                        for (r, slot) in rc.iter_mut().enumerate() {
                            *slot = Some(run_shard(ci * chunk + r));
                        }
                    });
                }
            });
        }
        results.into_iter().map(|r| r.expect("shard executed")).collect()
    }
}

/// A device (or host) buffer on some backend.
pub enum Buffer {
    Host(ArrayValue),
    Pjrt(xla::PjRtBuffer),
}

pub struct Runtime {
    backend: Backend,
    pjrt: Option<xla::PjRtClient>,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
    /// interpreter worker bound: 0 ⇒ all cores (resolved at use), n ⇒ n
    threads: AtomicUsize,
}

impl Runtime {
    /// Default runtime: backend selected by `QN_BACKEND` (interp unless
    /// overridden), single-threaded until [`Runtime::set_threads`].
    pub fn cpu() -> Result<Runtime> {
        Runtime::with_backend(Backend::from_env()?)
    }

    /// The interpreter backend, unconditionally (what the fixture-driven
    /// integration tests use).
    pub fn interp() -> Runtime {
        Runtime {
            backend: Backend::Interp,
            pjrt: None,
            cache: Mutex::new(HashMap::new()),
            threads: AtomicUsize::new(1),
        }
    }

    pub fn with_backend(backend: Backend) -> Result<Runtime> {
        let pjrt = match backend {
            Backend::Interp => None,
            Backend::Pjrt => Some(xla::PjRtClient::cpu().context("creating PJRT CPU client")?),
        };
        Ok(Runtime {
            backend,
            pjrt,
            cache: Mutex::new(HashMap::new()),
            threads: AtomicUsize::new(1),
        })
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Bound the interpreter's worker threads (`0` ⇒ all cores). Takes
    /// `&self` so a shared runtime can be tuned by the coordinator
    /// (`TrainConfig.threads` flows here). Thread count never changes
    /// results — only wall-clock.
    pub fn set_threads(&self, threads: usize) {
        self.threads.store(threads, Ordering::Relaxed);
    }

    /// Effective interpreter worker count. Resolution (0 ⇒ all cores)
    /// is shared with the host quantization engine so the one knob
    /// means the same thing on both sides.
    pub fn threads(&self) -> usize {
        crate::quant::assign::resolve_threads(self.threads.load(Ordering::Relaxed))
    }

    pub fn platform(&self) -> String {
        match (&self.backend, &self.pjrt) {
            (Backend::Interp, _) => "interp-cpu".to_string(),
            (Backend::Pjrt, Some(c)) => c.platform_name(),
            (Backend::Pjrt, None) => unreachable!("PJRT backend without client"),
        }
    }

    /// Load + compile an HLO text file (cached per-runtime by path,
    /// process-wide by content — see [`plan_cache_stats`]). On the
    /// interpreter backend "compile" is parse + plan lowering
    /// (liveness, move flags, fused-region/loop classification).
    pub fn compile(&self, path: &Path) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let exe = Arc::new(match self.backend {
            Backend::Interp => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading HLO text {}", path.display()))?;
                Executable::Interp(plan_for_text(&text, path)?)
            }
            Backend::Pjrt => {
                let client = self.pjrt.as_ref().expect("PJRT backend without client");
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )
                .map_err(|e| pjrt_err(e, &format!("parsing HLO text {}", path.display())))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                Executable::Pjrt(
                    client
                        .compile(&comp)
                        .map_err(|e| pjrt_err(e, &format!("compiling {}", path.display())))?,
                )
            }
        });
        self.cache.lock().unwrap().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    // ------------------------------------------------ host ⇄ device ---

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        match self.backend {
            Backend::Interp => Ok(Buffer::Host(
                ArrayValue::new(dims.to_vec(), Buf::F32(data.to_vec()))
                    .context("uploading f32 buffer")?,
            )),
            Backend::Pjrt => {
                let client = self.pjrt.as_ref().expect("PJRT backend without client");
                Ok(Buffer::Pjrt(
                    client
                        .buffer_from_host_buffer(data, dims, None)
                        .context("uploading f32 buffer")?,
                ))
            }
        }
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        match self.backend {
            Backend::Interp => Ok(Buffer::Host(
                ArrayValue::new(dims.to_vec(), Buf::S32(data.to_vec()))
                    .context("uploading i32 buffer")?,
            )),
            Backend::Pjrt => {
                let client = self.pjrt.as_ref().expect("PJRT backend without client");
                Ok(Buffer::Pjrt(
                    client
                        .buffer_from_host_buffer(data, dims, None)
                        .context("uploading i32 buffer")?,
                ))
            }
        }
    }

    pub fn scalar_f32(&self, v: f32) -> Result<Buffer> {
        self.upload_f32(&[v], &[])
    }

    pub fn scalar_i32(&self, v: i32) -> Result<Buffer> {
        self.upload_i32(&[v], &[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_backend_is_default_and_uploads() {
        let rt = Runtime::interp();
        assert_eq!(rt.backend(), Backend::Interp);
        assert_eq!(rt.platform(), "interp-cpu");
        let b = rt.upload_f32(&[1.0, 2.0], &[2]).unwrap();
        match b {
            Buffer::Host(a) => assert_eq!(a.as_f32().unwrap(), &[1.0, 2.0]),
            Buffer::Pjrt(_) => panic!("interp runtime produced a PJRT buffer"),
        }
        // shape mismatches are rejected at upload time
        assert!(rt.upload_f32(&[1.0; 5], &[2, 2]).is_err());
        // scalars are rank-0 one-element arrays
        match rt.scalar_i32(7).unwrap() {
            Buffer::Host(a) => {
                assert!(a.dims.is_empty());
                assert_eq!(*a.buf, Buf::S32(vec![7]));
            }
            Buffer::Pjrt(_) => panic!(),
        }
    }

    #[test]
    fn threads_knob_resolves_zero_to_cores() {
        let rt = Runtime::interp();
        assert_eq!(rt.threads(), 1); // conservative default
        rt.set_threads(3);
        assert_eq!(rt.threads(), 3);
        rt.set_threads(0);
        assert!(rt.threads() >= 1); // all cores
    }

    #[test]
    fn pjrt_stub_surfaces_typed_backend_error() {
        let dir = crate::util::testing::temp_dir("pjrt_typed_err");
        let path = dir.join("m.hlo.txt");
        std::fs::write(&path, "HloModule m\n").unwrap();
        let rt = Runtime::with_backend(Backend::Pjrt).unwrap();
        // compile declines via the stub: typed payload, even wrapped
        let err = rt.compile(&path).unwrap_err().context("serving model");
        let be = err.downcast_ref::<BackendError>().expect("BackendError payload");
        assert_eq!(be.backend, Backend::Pjrt);
        assert!(be.what.contains("parsing HLO text"), "{}", be.what);
        // batched execution is interpreter-only: also typed
        let exe = Executable::Pjrt(xla::PjRtLoadedExecutable);
        let err = exe.execute_f32_batched(&[], 2).unwrap_err();
        assert!(err.is::<BackendError>());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn pjrt_backend_still_constructs() {
        // the stub client builds; real compile/execute paths error — the
        // seam itself must stay usable for a future real xla crate
        let rt = Runtime::with_backend(Backend::Pjrt).unwrap();
        // don't assert the exact platform string: a real vendored xla
        // reports its own name, and this test must keep passing then
        assert!(!rt.platform().is_empty() && rt.platform() != "interp-cpu");
        assert!(rt.upload_f32(&[0.5], &[1]).is_ok());
        assert!(rt.compile(Path::new("/nonexistent.hlo.txt")).is_err());
    }

    #[test]
    fn plan_cache_shares_plans_by_content() {
        // same module text at two different paths, loaded by two
        // different runtimes: the second load must hit the content
        // cache (uniquely-named module so concurrent tests can't
        // interfere with the delta accounting)
        if std::env::var_os("QN_INTERP_STATS").is_some() {
            return; // stats mode intentionally bypasses the cache
        }
        let dir = crate::util::testing::temp_dir("plan_cache");
        let text = "HloModule plan_cache_probe_v1\n\nENTRY main.1 {\n  \
                    x.1 = f32[2]{0} parameter(0)\n  \
                    ROOT d.2 = f32[2]{0} add(x.1, x.1)\n}\n";
        let (pa, pb) = (dir.join("a.hlo.txt"), dir.join("b.hlo.txt"));
        std::fs::write(&pa, text).unwrap();
        std::fs::write(&pb, text).unwrap();
        let (h0, m0) = plan_cache_stats();
        let ra = Runtime::interp();
        ra.compile(&pa).unwrap();
        let (h1, m1) = plan_cache_stats();
        assert!(m1 > m0, "first load must miss ({m0} -> {m1})");
        let rb = Runtime::interp();
        rb.compile(&pb).unwrap();
        let (h2, _) = plan_cache_stats();
        assert!(h2 > h1, "same-content load must hit ({h1} -> {h2})");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn compile_caches_by_path() {
        let dir = crate::util::testing::temp_dir("interp_cache");
        let path = dir.join("m.hlo.txt");
        std::fs::write(
            &path,
            "HloModule m\n\nENTRY main.1 {\n  x.1 = f32[2]{0} parameter(0)\n  \
             ROOT d.2 = f32[2]{0} add(x.1, x.1)\n}\n",
        )
        .unwrap();
        let rt = Runtime::interp();
        let a = rt.compile(&path).unwrap();
        let b = rt.compile(&path).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second compile must hit the cache");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn execute_f32_runs_tuple_entry() {
        let dir = crate::util::testing::temp_dir("interp_exec");
        let path = dir.join("m.hlo.txt");
        std::fs::write(
            &path,
            "HloModule m\n\nENTRY main.1 {\n  x.1 = f32[2]{0} parameter(0)\n  \
             s.2 = f32[2]{0} multiply(x.1, x.1)\n  \
             ROOT t.3 = (f32[2]{0}, f32[2]{0}) tuple(x.1, s.2)\n}\n",
        )
        .unwrap();
        let rt = Runtime::interp();
        let exe = rt.compile(&path).unwrap();
        let arg = rt.upload_f32(&[3.0, -2.0], &[2]).unwrap();
        let out = exe.execute_f32(&[&arg]).unwrap();
        assert_eq!(out, vec![vec![3.0, -2.0], vec![9.0, 4.0]]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn execute_f32_batched_shards_leading_dim() {
        let dir = crate::util::testing::temp_dir("interp_batched");
        let path = dir.join("m.hlo.txt");
        // entry over a [2,3] batch plus a shared scale: per-shard sums
        std::fs::write(
            &path,
            "HloModule m\n\nsum.1 {\n  a.1 = f32[] parameter(0)\n  \
             b.2 = f32[] parameter(1)\n  ROOT add.3 = f32[] add(a.1, b.2)\n}\n\n\
             ENTRY main.1 {\n  x.1 = f32[2,3]{1,0} parameter(0)\n  \
             w.2 = f32[] parameter(1)\n  wb.3 = f32[2,3]{1,0} broadcast(w.2), \
             dimensions={}\n  m.4 = f32[2,3]{1,0} multiply(x.1, wb.3)\n  \
             z.5 = f32[] constant(0)\n  s.6 = f32[] reduce(m.4, z.5), \
             dimensions={0,1}, to_apply=sum.1\n  \
             ROOT t.7 = (f32[]) tuple(s.6)\n}\n",
        )
        .unwrap();
        let rt = Runtime::interp();
        let exe = rt.compile(&path).unwrap();
        let scale = rt.scalar_f32(2.0).unwrap();
        // macro-batch of M=3 shards, each [2,3]
        let data: Vec<f32> = (0..18).map(|i| i as f32).collect();
        let macro_arg = rt.upload_f32(&data, &[6, 3]).unwrap();
        for threads in [1usize, 3, 8] {
            let out = exe.execute_f32_batched(&[&macro_arg, &scale], threads).unwrap();
            assert_eq!(out.len(), 3, "threads={threads}");
            // shard s sums 2*(6 values starting at 6s)
            for (s, parts) in out.iter().enumerate() {
                let want: f32 = (0..6).map(|i| 2.0 * (s * 6 + i) as f32).sum();
                assert_eq!(parts[0], vec![want], "shard {s} threads={threads}");
            }
        }
        // per-shard results equal individual unbatched invocations
        let one = rt.upload_f32(&data[..6], &[2, 3]).unwrap();
        let single = exe.execute_f32(&[&one, &scale]).unwrap();
        let batched = exe.execute_f32_batched(&[&macro_arg, &scale], 2).unwrap();
        assert_eq!(single, batched[0]);
        // M=1 (exact entry shape) degrades to a single invocation
        let m1 = exe.execute_f32_batched(&[&one, &scale], 4).unwrap();
        assert_eq!(m1.len(), 1);
        assert_eq!(m1[0], single);
        // non-divisible leading dim is rejected
        let bad = rt.upload_f32(&data[..9], &[3, 3]).unwrap();
        assert!(exe.execute_f32_batched(&[&bad, &scale], 2).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
