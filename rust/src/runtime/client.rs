//! PJRT client wrapper: loads HLO-text artifacts, compiles them (with a
//! per-path cache), and owns the device handle. The pattern follows
//! /opt/xla-example/load_hlo — HLO *text* is the interchange format.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Mutex;

use anyhow::{Context, Result};

pub struct Runtime {
    pub client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by path).
    pub fn compile(&self, path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache.lock().unwrap().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    // ------------------------------------------------ host ⇄ device ---

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading f32 buffer")
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading i32 buffer")
    }

    pub fn scalar_f32(&self, v: f32) -> Result<xla::PjRtBuffer> {
        self.upload_f32(&[v], &[])
    }

    pub fn scalar_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
        self.upload_i32(&[v], &[])
    }
}

/// Download a tuple-output execution result as a vector of f32 vectors
/// (one per tuple element). All our artifacts return flat f32 tuples.
pub fn tuple_to_f32(result: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Vec<f32>>> {
    let buf = &result[0][0];
    let lit = buf.to_literal_sync().context("downloading result")?;
    let parts = lit.to_tuple().context("decomposing result tuple")?;
    parts
        .into_iter()
        .map(|p| p.to_vec::<f32>().context("tuple element to f32"))
        .collect()
}
