//! Runtime layer: manifest loading, HLO-text compilation, and typed
//! grad/eval sessions with persistent buffers, on a selectable backend
//! (pure-Rust interpreter or PJRT — see DESIGN.md §4).
pub mod client;
pub mod executable;
pub mod interp;
pub mod manifest;
