//! PJRT runtime: manifest loading, HLO-text compilation (pattern from
//! /opt/xla-example/load_hlo), and typed grad/eval sessions with
//! persistent device buffers.
pub mod client;
pub mod executable;
pub mod manifest;
